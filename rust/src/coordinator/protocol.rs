//! The **v1** planner-service wire dialect: one flat JSON object per
//! line, planning only. Kept alive behind the protocol-v2 adapter in
//! [`crate::api::wire`] — a request without a `"v"` field decodes here,
//! and is answered in this module's response shape, so pre-v2 clients
//! keep working unchanged (pinned by the back-compat tests in
//! `tests/test_api.rs`). New integrations should speak v2; see
//! `docs/PROTOCOL.md`.
//!
//! Request:
//! ```json
//! {"op": "plan", "mu": 60000, "c": 600, "d": 60, "r": 600,
//!  "recall": 0.85, "precision": 0.82, "window": 300,
//!  "alpha": 0.27, "migration": 300}
//! ```
//! (`ef` defaults to window/2; `op` defaults to "plan". `{"op":"stats"}`
//! and `{"op":"ping"}` are also understood.)
//!
//! Response:
//! ```json
//! {"ok": true, "winner": "ExactPrediction", "q": 1,
//!  "winner_waste": 0.12, "winner_period": 8123.4,
//!  "strategies": [{"name": "Young", "waste": ..., "period": ...}, ...]}
//! ```

use crate::config::Predictor;
use crate::model::{Params, StrategyKind};
use crate::runtime::PlanOutput;
use crate::util::json::{parse, Json};

/// Parsed request.
#[derive(Debug, Clone)]
pub enum Request {
    Plan(Params),
    Stats,
    Ping,
}

pub fn parse_request(line: &str) -> anyhow::Result<Request> {
    let v = parse(line)?;
    match v.get("op").and_then(Json::as_str).unwrap_or("plan") {
        "stats" => Ok(Request::Stats),
        "ping" => Ok(Request::Ping),
        "plan" => {
            let mu = v.num_or("mu", f64::NAN);
            anyhow::ensure!(mu.is_finite() && mu > 0.0, "plan request needs positive 'mu'");
            let window = v.num_or("window", 0.0);
            let p = Params {
                mu,
                c: v.num_or("c", 600.0),
                d: v.num_or("d", 60.0),
                r_rec: v.num_or("r", 600.0),
                recall: v.num_or("recall", 0.0),
                precision: v.num_or("precision", 1.0),
                i: window,
                ef: v.num_or("ef", window / 2.0),
                alpha: v.num_or("alpha", 0.27),
                m: v.num_or("migration", 300.0),
            };
            // Predictor validation is delegated to the typed layer so
            // the wire cannot drift from `Predictor::validate` — in
            // particular the degenerate no-predictor case
            // (recall = 0, precision = 0) is legal here too.
            Predictor { recall: p.recall, precision: p.precision, window: p.i, ef: p.ef }
                .validate()?;
            Ok(Request::Plan(p))
        }
        other => anyhow::bail!("unknown op '{other}'"),
    }
}

pub fn plan_response(out: &PlanOutput) -> String {
    let strategies: Vec<Json> = StrategyKind::ALL
        .iter()
        .map(|k| {
            Json::obj(vec![
                ("name", Json::Str(k.name().into())),
                ("waste", Json::Num(out.waste[*k as usize])),
                ("period", Json::Num(out.period[*k as usize])),
            ])
        })
        .collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("winner", Json::Str(out.winner.name().into())),
        ("q", Json::Num(if out.winner == StrategyKind::Young { 0.0 } else { 1.0 })),
        ("winner_waste", Json::Num(out.winner_waste)),
        ("winner_period", Json::Num(out.winner_period)),
        ("strategies", Json::Arr(strategies)),
    ])
    .to_string()
}

pub fn error_response(err: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(err.into()))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_plan_request() {
        let r = parse_request(
            r#"{"mu": 60000, "recall": 0.85, "precision": 0.82, "window": 300}"#,
        )
        .unwrap();
        match r {
            Request::Plan(p) => {
                assert_eq!(p.mu, 60000.0);
                assert_eq!(p.ef, 150.0); // window / 2 default
                assert_eq!(p.c, 600.0);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_verbs() {
        assert!(matches!(parse_request(r#"{"op": "ping"}"#).unwrap(), Request::Ping));
        assert!(matches!(parse_request(r#"{"op": "stats"}"#).unwrap(), Request::Stats));
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request(r#"{"op": "plan"}"#).is_err()); // no mu
        assert!(parse_request(r#"{"mu": -5}"#).is_err());
        assert!(parse_request(r#"{"mu": 100, "recall": 2.0}"#).is_err());
        assert!(parse_request(r#"{"mu": 100, "recall": 0.5, "precision": 0}"#).is_err());
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"op": "destroy"}"#).is_err());
    }

    #[test]
    fn degenerate_no_predictor_case_is_accepted() {
        // recall = 0, precision = 0 is the paper's "no predictor at
        // all" point; the wire must agree with `Predictor::validate`.
        let r = parse_request(r#"{"mu": 60000, "recall": 0, "precision": 0}"#).unwrap();
        match r {
            Request::Plan(p) => {
                assert_eq!(p.recall, 0.0);
                assert_eq!(p.precision, 0.0);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn response_shape() {
        let out = PlanOutput {
            waste: [0.2, 0.1, 0.12, 0.13, 0.14, 0.09],
            period: [1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            winner: StrategyKind::ExactPrediction,
            winner_waste: 0.1,
            winner_period: 2.0,
        };
        let s = plan_response(&out);
        let v = crate::util::json::parse(&s).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("winner").unwrap().as_str(), Some("ExactPrediction"));
        assert_eq!(v.num_or("q", -1.0), 1.0);
    }
}
