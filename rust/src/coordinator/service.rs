//! The job service: an async multiplexed TCP server speaking the JSONL
//! job protocol (v2, with the v1 planner dialect adapted
//! transparently). One event-loop thread owns the nonblocking listener
//! and every connection; a small pool of executor lanes drains
//! per-tenant job queues under stride (weighted-fair) scheduling;
//! every request is dispatched through a shared [`Executor`] — the
//! same entry points the CLI and the experiment harness use
//! in-process.
//!
//! The service practices what the paper preaches about fault
//! tolerance:
//!
//! * **Admission control** — connection, in-flight and per-tenant
//!   queue gates shed load with a structured `overloaded` error
//!   (carrying `retry_after_ms`) instead of queueing without bound.
//! * **Fair scheduling** — queued jobs are drained by stride
//!   scheduling across tenants ([`Scheduler`]): each tenant advances a
//!   virtual "pass" by `STRIDE_ONE / weight` per dispatch, the minimum
//!   pass runs next, and a global floor stops a returning idle tenant
//!   from claiming the shares it never used. Deterministic, so the
//!   fairness property is unit-tested without timing.
//! * **Request guards** — a per-request deadline rides the executor's
//!   [`crate::util::cancel::CancelToken`]; oversized lines are
//!   rejected without decoding; idle connections time out.
//! * **Panic isolation** — `catch_unwind` at the request and
//!   per-connection line/flush boundaries turns a poisoned request
//!   into an `internal` error on that one response (or one dead
//!   connection), never a dead service.
//! * **Graceful drain** — [`ServiceHandle::stop`] stops accepting,
//!   lets admitted jobs finish and their responses flush up to a drain
//!   deadline, then cancels cooperatively and joins every thread. No
//!   loopback nudge: the event loop polls its stop flag, so stopping a
//!   zero-connection service is immediate and leak-free.
//!
//! Streaming: a v2 request carrying `"stream": true` gets its
//! `sweep`/`verify` response as additive partial frames (one per
//! row/case) followed by a final frame — see `wire::stream_items` and
//! docs/PROTOCOL.md. Non-streamed responses are byte-identical to the
//! thread-per-connection era.

use std::any::Any;
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::metrics::lock_unpoisoned;
use crate::api::{wire, ApiError, ErrorCode, Executor, JobRequest, JobResponse};
use crate::util::cancel::CancelToken;
use crate::util::json::Json;

/// Event-loop tick: how long the loop sleeps after the *first* idle
/// pass. Bounds stop latency and completion-delivery latency while
/// traffic is flowing.
const TICK: Duration = Duration::from_millis(1);

/// Idle-backoff ceiling: consecutive idle passes double the sleep from
/// [`TICK`] up to here, then hold. A long-idle service burns ~100
/// wakeups/s instead of ~1000; the first readiness of any kind (accept,
/// read, completion, flush) resets the sleep to [`TICK`], so the worst
/// added latency for the request that *ends* an idle stretch is one
/// ceiling tick. No wire-visible behavior changes — this only retunes
/// when the loop polls.
const TICK_IDLE_MAX: Duration = Duration::from_millis(10);

/// Reads hard-close past this much buffered line data: beyond it there
/// is no trustworthy message boundary to resync on. Lines between
/// [`wire::MAX_LINE_BYTES`] and this bound still get a structured
/// `bad_request` and a surviving connection.
const HARD_LINE_LIMIT: usize = wire::MAX_LINE_BYTES * 4;

/// How long a shed (over-`max_conns`) connection is given to present
/// its first line, so the rejection can speak the caller's dialect.
const SHED_READ_BUDGET: Duration = Duration::from_secs(1);

/// One stride unit: a tenant's pass advances by `STRIDE_ONE / weight`
/// per dispatched job, so weight-w tenants run w times as often.
const STRIDE_ONE: u64 = 1 << 32;

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bind address, e.g. "127.0.0.1:7471". Port 0 picks a free port.
    pub addr: String,
    /// Connection gate: accepts past this many live connections are
    /// answered `overloaded` and closed.
    pub max_conns: usize,
    /// Job gate: requests (other than `ping`/`stats`) past this many
    /// admitted (queued + executing) jobs are answered `overloaded`;
    /// the connection survives.
    pub max_inflight: usize,
    /// Per-request wall-clock budget threaded into the executor.
    /// `None` disables the guard.
    pub deadline: Option<Duration>,
    /// How long [`ServiceHandle::stop`] waits for admitted jobs
    /// before cancelling them cooperatively.
    pub drain: Duration,
    /// Connections with no traffic for this long are closed.
    pub idle_timeout: Duration,
    /// Retry hint carried by `overloaded` responses.
    pub retry_after_ms: u64,
    /// Per-tenant bound on *queued* (admitted, not yet executing)
    /// jobs; one tenant's burst sheds at this depth instead of
    /// consuming the whole global `max_inflight` budget.
    pub queue_depth: usize,
    /// Executor lanes draining the tenant queues. `0` (the default)
    /// means one lane per `max_inflight` slot — the same concurrency
    /// as the old thread-per-connection dispatch.
    pub sched_workers: usize,
    /// Fair-share weights by tenant name; unlisted tenants (and the
    /// anonymous tenant `""`) weigh 1.
    pub tenant_weights: Vec<(String, u64)>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:7471".into(),
            max_conns: 256,
            max_inflight: 32,
            deadline: None,
            drain: Duration::from_secs(5),
            idle_timeout: Duration::from_secs(300),
            retry_after_ms: 250,
            queue_depth: 32,
            sched_workers: 0,
            tenant_weights: Vec::new(),
        }
    }
}

// ---------------------------------------------------------------------------
// Stride scheduler
// ---------------------------------------------------------------------------

/// One admitted job, queued until an executor lane picks it up.
struct QueuedJob {
    conn: u64,
    slot: u64,
    request: JobRequest,
    legacy: bool,
    stream: bool,
}

struct TenantQueue {
    q: VecDeque<QueuedJob>,
    /// Virtual time: advances by `stride` per dispatched job.
    pass: u64,
    stride: u64,
}

#[derive(Default)]
struct SchedState {
    tenants: BTreeMap<String, TenantQueue>,
    queued: usize,
    running: usize,
    /// The largest pass ever dispatched — the scheduler's virtual
    /// clock. A tenant going from idle to busy starts at this floor,
    /// so idle time is forfeited, not banked.
    floor: u64,
    shutdown: bool,
}

/// Weighted-fair job queue: stride scheduling over per-tenant FIFOs.
/// Deterministic — the dispatch order is a pure function of the
/// enqueue order and the weights — which is what makes the fairness
/// tests below exact rather than statistical.
struct Scheduler {
    state: Mutex<SchedState>,
    cv: Condvar,
    weights: Vec<(String, u64)>,
}

impl Scheduler {
    fn new(weights: Vec<(String, u64)>) -> Scheduler {
        Scheduler { state: Mutex::new(SchedState::default()), cv: Condvar::new(), weights }
    }

    fn weight(&self, tenant: &str) -> u64 {
        self.weights
            .iter()
            .find(|(name, _)| name == tenant)
            .map(|&(_, w)| w)
            .filter(|&w| w > 0)
            .unwrap_or(1)
    }

    /// Admitted jobs: queued + executing. The global admission gate.
    fn load(&self) -> usize {
        let st = lock_unpoisoned(&self.state);
        st.queued + st.running
    }

    /// Queued (not yet executing) jobs for one tenant — the per-tenant
    /// admission gate.
    fn tenant_depth(&self, tenant: &str) -> usize {
        lock_unpoisoned(&self.state).tenants.get(tenant).map_or(0, |t| t.q.len())
    }

    fn enqueue(&self, tenant: &str, job: QueuedJob) {
        let stride = STRIDE_ONE / self.weight(tenant);
        let mut st = lock_unpoisoned(&self.state);
        let floor = st.floor;
        let tq = st
            .tenants
            .entry(tenant.to_string())
            .or_insert_with(|| TenantQueue { q: VecDeque::new(), pass: 0, stride });
        tq.stride = stride;
        if tq.q.is_empty() {
            // Re-entering the run queue: jump to the virtual clock so
            // accumulated idle time doesn't turn into a monopoly.
            tq.pass = tq.pass.max(floor);
        }
        tq.q.push_back(job);
        st.queued += 1;
        self.cv.notify_one();
    }

    /// Block until a job is runnable (or shutdown): minimum pass wins,
    /// ties break to the lexicographically smallest tenant name.
    fn next(&self) -> Option<QueuedJob> {
        let mut st = lock_unpoisoned(&self.state);
        loop {
            if st.shutdown {
                return None;
            }
            let mut pick: Option<(&String, u64)> = None;
            for (name, tq) in st.tenants.iter() {
                if tq.q.is_empty() {
                    continue;
                }
                // Strict `<` keeps the first (smallest-name) tenant on
                // a pass tie — BTreeMap iterates in key order.
                if pick.map_or(true, |(_, pass)| tq.pass < pass) {
                    pick = Some((name, tq.pass));
                }
            }
            if let Some((name, _)) = pick {
                let name = name.clone();
                let tq = st.tenants.get_mut(&name).expect("picked tenant exists");
                let job = tq.q.pop_front().expect("picked tenant has a job");
                let pass = tq.pass;
                tq.pass = tq.pass.saturating_add(tq.stride);
                st.floor = st.floor.max(pass);
                st.queued -= 1;
                st.running += 1;
                return Some(job);
            }
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn done(&self) {
        let mut st = lock_unpoisoned(&self.state);
        st.running = st.running.saturating_sub(1);
    }

    fn shutdown(&self) {
        lock_unpoisoned(&self.state).shutdown = true;
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Shared state + handle
// ---------------------------------------------------------------------------

/// State shared by the event loop, the executor lanes and the handle.
struct Shared {
    /// Graceful-stop flag: stop accepting, drain admitted jobs.
    stop: AtomicBool,
    /// Hard-cancel flag, set once the drain deadline passes; also the
    /// cancel flag threaded into executing jobs.
    hard_cancel: Arc<AtomicBool>,
    sched: Scheduler,
    cfg: ServiceConfig,
}

/// A finished job's response lines, headed back to its connection.
struct Completion {
    conn: u64,
    slot: u64,
    lines: Vec<String>,
}

/// Running service handle: local address + shutdown control.
pub struct ServiceHandle {
    pub addr: SocketAddr,
    shared: Arc<Shared>,
    join: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServiceHandle {
    /// Graceful drain: stop accepting, let admitted jobs finish and
    /// their responses flush up to the configured drain deadline, then
    /// cancel cooperatively and join every thread. The event loop
    /// polls the stop flag each tick, so no nudge connection is needed
    /// and a zero-connection stop returns immediately.
    pub fn stop(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        self.shared.sched.shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Start serving in background threads. The executor (its batcher
/// handle, metrics and plan cache) is shared across every lane.
pub fn serve(executor: Executor, cfg: ServiceConfig) -> anyhow::Result<ServiceHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let lanes = if cfg.sched_workers == 0 { cfg.max_inflight.max(1) } else { cfg.sched_workers };
    let shared = Arc::new(Shared {
        stop: AtomicBool::new(false),
        hard_cancel: Arc::new(AtomicBool::new(false)),
        sched: Scheduler::new(cfg.tenant_weights.clone()),
        cfg,
    });
    let (tx, rx) = std::sync::mpsc::channel::<Completion>();
    let mut workers = Vec::with_capacity(lanes);
    for i in 0..lanes {
        let shared = Arc::clone(&shared);
        let executor = executor.clone();
        let tx = tx.clone();
        workers.push(
            std::thread::Builder::new()
                .name(format!("ckptfp-exec-{i}"))
                .spawn(move || worker_loop(&shared, &executor, &tx))?,
        );
    }
    drop(tx);
    let shared2 = Arc::clone(&shared);
    let join = std::thread::Builder::new()
        .name("ckptfp-service".into())
        .spawn(move || event_loop(listener, &executor, &shared2, &rx))?;
    Ok(ServiceHandle { addr, shared, join: Some(join), workers })
}

fn overloaded_error(cfg: &ServiceConfig, what: &str, limit: usize) -> ApiError {
    ApiError::overloaded(
        format!(
            "service at capacity ({limit} {what}); retry after {} ms",
            cfg.retry_after_ms
        ),
        cfg.retry_after_ms,
    )
}

fn panic_error(payload: Box<dyn Any + Send>) -> ApiError {
    let msg = payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".into());
    ApiError::new(ErrorCode::Internal, format!("request handler panicked: {msg}"))
}

/// Execute one request under cooperative cancellation and per-request
/// panic containment — shared by the executor lanes and the inline
/// (`ping`/`stats`) path.
fn run_guarded(executor: &Executor, shared: &Shared, req: &JobRequest) -> JobResponse {
    let cancel = CancelToken::with_flag(Arc::clone(&shared.hard_cancel));
    match catch_unwind(AssertUnwindSafe(|| executor.execute_cancellable(req, &cancel))) {
        Ok(resp) => resp,
        Err(payload) => {
            executor.note_panic_contained();
            JobResponse::Error(panic_error(payload))
        }
    }
}

/// Encode one response as its wire line(s): a streamed v2 sweep/verify
/// becomes partial frames plus a final frame; everything else is the
/// single line the thread-per-connection service wrote, byte for byte.
fn response_lines(resp: &JobResponse, legacy: bool, stream: bool) -> Vec<String> {
    if !legacy && stream {
        if let Some((job, items)) = wire::stream_items(resp) {
            let n = items.len() as u64;
            let mut lines: Vec<String> = items
                .into_iter()
                .enumerate()
                .map(|(i, item)| wire::encode_stream_partial(job, i as u64, item))
                .collect();
            lines.push(wire::encode_stream_final(resp, n));
            return lines;
        }
    }
    vec![wire::encode_response(resp, legacy)]
}

/// One executor lane: pull jobs off the fair scheduler, run them, send
/// the encoded lines back to the event loop for in-order delivery.
fn worker_loop(shared: &Shared, executor: &Executor, tx: &Sender<Completion>) {
    while let Some(job) = shared.sched.next() {
        let resp = run_guarded(executor, shared, &job.request);
        let lines = response_lines(&resp, job.legacy, job.stream);
        shared.sched.done();
        // A send error means the event loop is gone, which only
        // happens after the scheduler drained; drop the lines.
        let _ = tx.send(Completion { conn: job.conn, slot: job.slot, lines });
    }
}

// ---------------------------------------------------------------------------
// Connections
// ---------------------------------------------------------------------------

/// One multiplexed connection, owned by the event loop.
struct Conn {
    stream: TcpStream,
    /// Unconsumed inbound bytes (at most one partial line after
    /// processing).
    buf: Vec<u8>,
    /// Outbound bytes not yet accepted by the socket.
    out: Vec<u8>,
    out_pos: usize,
    /// Response slots are assigned in request-arrival order and
    /// delivered strictly in order, so pipelined clients see v1
    /// semantics.
    next_slot: u64,
    deliver_next: u64,
    ready: BTreeMap<u64, Vec<String>>,
    /// Slots waiting on an executor-lane completion.
    outstanding: usize,
    last_activity: Instant,
    peer_closed: bool,
    dead: bool,
    /// `Some(deadline)`: an over-`max_conns` connection being shed —
    /// it gets one line's worth of patience (to answer in the caller's
    /// dialect), an `overloaded` reply, and the boot.
    shed: Option<Instant>,
    shed_replied: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            buf: Vec::new(),
            out: Vec::new(),
            out_pos: 0,
            next_slot: 0,
            deliver_next: 0,
            ready: BTreeMap::new(),
            outstanding: 0,
            last_activity: Instant::now(),
            peer_closed: false,
            dead: false,
            shed: None,
            shed_replied: false,
        }
    }

    fn alloc_slot(&mut self) -> u64 {
        let s = self.next_slot;
        self.next_slot += 1;
        s
    }

    /// An immediately-answered slot (errors, ping/stats, admission
    /// rejections): allocated and completed in one step, so it still
    /// respects arrival order relative to queued jobs.
    fn push_inline(&mut self, lines: Vec<String>) {
        let slot = self.alloc_slot();
        self.ready.insert(slot, lines);
    }

    fn has_output(&self) -> bool {
        self.out_pos < self.out.len() || !self.ready.is_empty()
    }
}

/// Decode and act on one complete request line. Mirrors the
/// thread-per-connection handler's order exactly: length guard before
/// UTF-8, UTF-8 before the chaos read hook, empty-line skip, then
/// decode → (inline answer | enqueue).
fn handle_line(conn: &mut Conn, conn_id: u64, raw: Vec<u8>, executor: &Executor, shared: &Shared) {
    if raw.len() > wire::MAX_LINE_BYTES {
        // Reject before decoding (and before requiring valid UTF-8);
        // sniff the dialect from the prefix only.
        executor.note_rejected();
        let head = String::from_utf8_lossy(&raw[..raw.len().min(256)]).into_owned();
        let e = ApiError::bad_request(format!(
            "request line of {} bytes exceeds the {} byte limit",
            raw.len(),
            wire::MAX_LINE_BYTES
        ));
        conn.push_inline(vec![wire::encode_response(
            &JobResponse::Error(e),
            wire::line_is_legacy(&head),
        )]);
        return;
    }
    let line = match String::from_utf8(raw) {
        Ok(l) => l,
        Err(_) => {
            executor.note_rejected();
            let e = ApiError::invalid_json("request line is not valid UTF-8");
            conn.push_inline(vec![wire::encode_response(&JobResponse::Error(e), false)]);
            return;
        }
    };
    #[cfg(any(test, feature = "chaos"))]
    let line = crate::chaos::mangle_service_read(line);
    if line.trim().is_empty() {
        return;
    }
    match wire::decode_request_meta(&line) {
        Err(e) => {
            executor.note_rejected();
            // Answer in the dialect the line arrived in: a v1 line
            // that failed validation still gets the legacy error
            // shape (no "v" marker). Unparseable lines default to
            // the v2 shape — both dialects read ok:false + error.
            conn.push_inline(vec![wire::encode_response(
                &JobResponse::Error(e),
                wire::line_is_legacy(&line),
            )]);
        }
        Ok((decoded, meta)) => {
            // `ping` and `stats` stay answerable under full load —
            // they are the probes an operator uses to see *why* the
            // service is shedding — so they bypass the queues.
            let gated = !matches!(decoded.request, JobRequest::Ping | JobRequest::Stats);
            if !gated {
                let resp = run_guarded(executor, shared, &decoded.request);
                conn.push_inline(vec![wire::encode_response(&resp, decoded.legacy)]);
                return;
            }
            if shared.sched.load() >= shared.cfg.max_inflight {
                executor.note_overloaded();
                let e =
                    overloaded_error(&shared.cfg, "jobs in flight", shared.cfg.max_inflight);
                conn.push_inline(vec![wire::encode_response(&JobResponse::Error(e), decoded.legacy)]);
                return;
            }
            let tenant = meta.tenant.as_deref().unwrap_or("");
            if shared.sched.tenant_depth(tenant) >= shared.cfg.queue_depth {
                executor.note_overloaded();
                let e = overloaded_error(&shared.cfg, "queued jobs", shared.cfg.queue_depth);
                conn.push_inline(vec![wire::encode_response(&JobResponse::Error(e), decoded.legacy)]);
                return;
            }
            let slot = conn.alloc_slot();
            conn.outstanding += 1;
            shared.sched.enqueue(
                tenant,
                QueuedJob {
                    conn: conn_id,
                    slot,
                    request: decoded.request,
                    legacy: decoded.legacy,
                    stream: meta.stream,
                },
            );
        }
    }
}

/// Answer a shed connection `overloaded` in the given dialect.
fn shed_reply(conn: &mut Conn, shared: &Shared, legacy: bool) {
    let e = overloaded_error(&shared.cfg, "connections", shared.cfg.max_conns);
    conn.push_inline(vec![wire::encode_response(&JobResponse::Error(e), legacy)]);
    conn.shed_replied = true;
}

/// Split complete lines out of `conn.buf` and handle each. A panic in
/// line handling (e.g. an injected ServiceRead panic) is contained to
/// this connection.
fn process_buffer(conn: &mut Conn, conn_id: u64, executor: &Executor, shared: &Shared) {
    loop {
        let Some(pos) = conn.buf.iter().position(|&b| b == b'\n') else { break };
        let mut raw: Vec<u8> = conn.buf.drain(..=pos).collect();
        raw.pop(); // the '\n'
        if raw.last() == Some(&b'\r') {
            raw.pop();
        }
        if conn.shed.is_some() {
            // First line decides the rejection dialect; the rest of
            // the stream is irrelevant.
            if !conn.shed_replied {
                let legacy = wire::line_is_legacy(&String::from_utf8_lossy(&raw));
                shed_reply(conn, shared, legacy);
            }
            conn.buf.clear();
            return;
        }
        let caught =
            catch_unwind(AssertUnwindSafe(|| handle_line(conn, conn_id, raw, executor, shared)));
        if caught.is_err() {
            executor.note_panic_contained();
            conn.dead = true;
            return;
        }
        if conn.dead {
            return;
        }
    }
}

/// Drain the socket's readable bytes into the line buffer. Returns
/// true if any progress was made.
fn read_conn(conn: &mut Conn, conn_id: u64, executor: &Executor, shared: &Shared) -> bool {
    let mut busy = false;
    let mut tmp = [0u8; 16 * 1024];
    loop {
        match conn.stream.read(&mut tmp) {
            Ok(0) => {
                conn.peer_closed = true;
                break;
            }
            Ok(n) => {
                busy = true;
                conn.last_activity = Instant::now();
                conn.buf.extend_from_slice(&tmp[..n]);
                process_buffer(conn, conn_id, executor, shared);
                if conn.dead {
                    break;
                }
                if conn.buf.len() > HARD_LINE_LIMIT {
                    // Past the resync horizon: no trustworthy message
                    // boundary remains; drop the connection.
                    conn.dead = true;
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    busy
}

/// Move in-order completed slots into the outbound buffer and push
/// bytes at the socket. Returns true if any progress was made. A panic
/// from the chaos write hook is contained by the caller.
fn flush_conn(conn: &mut Conn) -> bool {
    let mut busy = false;
    while let Some(lines) = conn.ready.remove(&conn.deliver_next) {
        conn.deliver_next += 1;
        busy = true;
        for line in lines {
            #[cfg(any(test, feature = "chaos"))]
            crate::chaos::on_service_write();
            conn.out.extend_from_slice(line.as_bytes());
            conn.out.push(b'\n');
        }
    }
    while conn.out_pos < conn.out.len() {
        match conn.stream.write(&conn.out[conn.out_pos..]) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => {
                conn.out_pos += n;
                conn.last_activity = Instant::now();
                busy = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    if conn.out_pos == conn.out.len() && !conn.out.is_empty() {
        conn.out.clear();
        conn.out_pos = 0;
        let _ = conn.stream.flush();
    }
    busy
}

/// The event loop: nonblocking accept, readiness-polled reads, job
/// admission, in-order response delivery, drain-aware shutdown.
fn event_loop(
    listener: TcpListener,
    executor: &Executor,
    shared: &Shared,
    completions: &Receiver<Completion>,
) {
    let mut conns: BTreeMap<u64, Conn> = BTreeMap::new();
    let mut next_id: u64 = 0;
    let mut draining = false;
    // Soft deadline: in-flight work gets `cfg.drain` to finish clean;
    // after that `hard_cancel` trips and cancelled work gets one more
    // `cfg.drain` to flush its partial responses before we give up.
    let mut drain_deadline: Option<Instant> = None;
    let mut hard_deadline: Option<Instant> = None;
    // Adaptive idle backoff: the current sleep for a no-progress pass.
    let mut idle_tick = TICK;
    loop {
        let mut busy = false;
        let now = Instant::now();

        if !draining && shared.stop.load(Ordering::SeqCst) {
            draining = true;
            drain_deadline = Some(now + shared.cfg.drain);
        }

        if !draining {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        busy = true;
                        let _ = stream.set_nonblocking(true);
                        let live = conns.values().filter(|c| c.shed.is_none() && !c.dead).count();
                        let mut conn = Conn::new(stream);
                        if live >= shared.cfg.max_conns {
                            // Over the connection gate: give the peer
                            // one line's worth of patience, then shed
                            // with a structured `overloaded`.
                            executor.note_overloaded();
                            conn.shed = Some(now + SHED_READ_BUDGET);
                        }
                        conns.insert(next_id, conn);
                        next_id += 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(_) => break,
                }
            }
            for (&id, conn) in conns.iter_mut() {
                if conn.dead || conn.peer_closed {
                    continue;
                }
                busy |= read_conn(conn, id, executor, shared);
            }
            // Shed connections whose line never came still get their
            // rejection (in the default v2 shape) at the deadline.
            for conn in conns.values_mut() {
                if let Some(d) = conn.shed {
                    if !conn.shed_replied && !conn.dead && now >= d {
                        shed_reply(conn, shared, false);
                        busy = true;
                    }
                }
            }
        }

        while let Ok(done) = completions.try_recv() {
            busy = true;
            if let Some(conn) = conns.get_mut(&done.conn) {
                conn.outstanding = conn.outstanding.saturating_sub(1);
                conn.ready.insert(done.slot, done.lines);
                conn.last_activity = Instant::now();
            }
            // A completion for a vanished connection is dropped: the
            // peer is gone and pure responses are reproducible.
        }

        for (&id, conn) in conns.iter_mut() {
            if conn.dead {
                continue;
            }
            let caught = catch_unwind(AssertUnwindSafe(|| flush_conn(conn)));
            match caught {
                Ok(b) => busy |= b,
                Err(_) => {
                    let _ = id;
                    executor.note_panic_contained();
                    conn.dead = true;
                }
            }
        }

        conns.retain(|_, c| {
            if c.dead {
                return false;
            }
            if c.shed_replied && !c.has_output() {
                return false;
            }
            if c.peer_closed && c.outstanding == 0 && !c.has_output() {
                return false;
            }
            if !draining
                && c.outstanding == 0
                && !c.has_output()
                && now.duration_since(c.last_activity) >= shared.cfg.idle_timeout
            {
                return false;
            }
            true
        });

        if draining {
            let work_left = shared.sched.load() > 0
                || conns.values().any(|c| c.outstanding > 0 || c.has_output());
            if !work_left {
                break;
            }
            if let Some(d) = drain_deadline {
                if now >= d {
                    shared.hard_cancel.store(true, Ordering::SeqCst);
                    drain_deadline = None;
                    hard_deadline = Some(now + shared.cfg.drain);
                }
            } else if let Some(h) = hard_deadline {
                if now >= h {
                    break;
                }
            }
        }

        if !busy {
            std::thread::sleep(idle_tick);
            idle_tick = (idle_tick * 2).min(TICK_IDLE_MAX);
        } else {
            idle_tick = TICK;
        }
    }
    // Dropping `conns` closes every socket; dropping the listener
    // frees the port. The handle joins the executor lanes next.
}

/// Minimal blocking *raw-line* client, for tests and tools that need
/// byte-level control over what goes on the wire (e.g. the v1
/// back-compat pins). Typed callers should use
/// [`crate::api::ServiceClient`] instead.
pub struct PlannerClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl PlannerClient {
    /// Read timeout applied to every [`PlannerClient`] connection — a
    /// wedged server is a clear error, not a hang.
    pub const READ_TIMEOUT: Duration = Duration::from_secs(30);

    pub fn connect(addr: &str) -> anyhow::Result<PlannerClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Self::READ_TIMEOUT))?;
        let writer = stream.try_clone()?;
        Ok(PlannerClient { reader: BufReader::new(stream), writer })
    }

    /// Send one JSONL request, read one JSONL response.
    pub fn call(&mut self, request: &str) -> anyhow::Result<Json> {
        self.writer.write_all(request.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line).map_err(|e| {
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) {
                anyhow::anyhow!(
                    "no response within the {:.0}s read timeout",
                    Self::READ_TIMEOUT.as_secs_f64()
                )
            } else {
                anyhow::Error::from(e)
            }
        })?;
        anyhow::ensure!(!line.is_empty(), "server closed the connection");
        crate::util::json::parse(line.trim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{SweepResult, SweepRow};
    use crate::model::StrategyKind;

    fn job(tag: u64) -> QueuedJob {
        QueuedJob { conn: 0, slot: tag, request: JobRequest::Ping, legacy: false, stream: false }
    }

    #[test]
    fn stride_scheduler_shares_dispatches_by_weight() {
        let s = Scheduler::new(vec![("heavy".into(), 3), ("light".into(), 1)]);
        for i in 0..40 {
            s.enqueue("heavy", job(i));
        }
        for i in 0..40 {
            s.enqueue("light", job(100 + i));
        }
        let (mut heavy, mut light) = (0, 0);
        for _ in 0..24 {
            let j = s.next().expect("queued work");
            s.done();
            if j.slot < 100 {
                heavy += 1;
            } else {
                light += 1;
            }
        }
        // Exact, not statistical: stride dispatch is deterministic.
        assert_eq!((heavy, light), (18, 6), "3:1 weights over 24 dispatches");
    }

    #[test]
    fn a_returning_idle_tenant_cannot_claim_the_past() {
        let s = Scheduler::new(Vec::new());
        for i in 0..10 {
            s.enqueue("a", job(i));
        }
        for _ in 0..10 {
            s.next().expect("queued work");
            s.done();
        }
        // "b" arrives late with pass 0; the floor forces it to share
        // from now on instead of monopolizing to "catch up".
        for i in 0..4 {
            s.enqueue("a", job(20 + i));
            s.enqueue("b", job(100 + i));
        }
        let (mut a, mut b) = (0, 0);
        for _ in 0..8 {
            let j = s.next().expect("queued work");
            s.done();
            if j.slot >= 100 {
                b += 1;
            } else {
                a += 1;
            }
        }
        assert_eq!((a, b), (4, 4), "equal weights share equally after the idle gap");
    }

    #[test]
    fn shutdown_unblocks_a_parked_lane() {
        let s = Arc::new(Scheduler::new(Vec::new()));
        let s2 = Arc::clone(&s);
        let lane = std::thread::spawn(move || s2.next());
        std::thread::sleep(Duration::from_millis(30));
        s.shutdown();
        assert!(lane.join().unwrap().is_none(), "shutdown must return None");
    }

    fn sweep_resp() -> JobResponse {
        JobResponse::Sweep(SweepResult {
            rows: vec![
                SweepRow {
                    n_procs: 1 << 16,
                    mu: 60133.0,
                    winner: StrategyKind::ExactPrediction,
                    winner_waste: 0.11,
                    winner_period: 9000.0,
                },
                SweepRow {
                    n_procs: 1 << 19,
                    mu: 7516.0,
                    winner: StrategyKind::Young,
                    winner_waste: 0.4,
                    winner_period: 3000.0,
                },
            ],
            via_hlo: false,
        })
    }

    #[test]
    fn streamed_sweeps_frame_every_row_then_finalize() {
        let resp = sweep_resp();
        let lines = response_lines(&resp, false, true);
        assert_eq!(lines.len(), 3, "2 partials + 1 final");
        for (i, line) in lines[..2].iter().enumerate() {
            match wire::decode_stream_event(line).unwrap() {
                wire::StreamEvent::Partial { job, seq, .. } => {
                    assert_eq!(job, "sweep");
                    assert_eq!(seq, i as u64);
                }
                other => panic!("expected a partial frame, got {other:?}"),
            }
        }
        match wire::decode_stream_event(&lines[2]).unwrap() {
            wire::StreamEvent::Final { seq, response } => {
                assert_eq!(seq, Some(2));
                assert_eq!(response, resp);
            }
            other => panic!("expected the final frame, got {other:?}"),
        }
    }

    #[test]
    fn unstreamed_and_legacy_responses_stay_single_line() {
        let resp = sweep_resp();
        // No stream flag: byte-identical to the plain encoding.
        assert_eq!(response_lines(&resp, false, false), vec![wire::encode_response(&resp, false)]);
        // The v1 dialect never streams, even if the flag sneaks in.
        assert_eq!(response_lines(&resp, true, true), vec![wire::encode_response(&resp, true)]);
        // Pong has no row shape to stream: the flag is harmlessly
        // ignored.
        assert_eq!(
            response_lines(&JobResponse::Pong, false, true),
            vec![wire::encode_response(&JobResponse::Pong, false)]
        );
    }

    #[test]
    fn overloaded_message_format_is_stable() {
        // The golden fixtures pin this exact phrasing.
        let e = overloaded_error(&ServiceConfig::default(), "jobs in flight", 32);
        assert_eq!(e.message, "service at capacity (32 jobs in flight); retry after 250 ms");
        assert_eq!(e.retry_after_ms, Some(250));
    }
}
