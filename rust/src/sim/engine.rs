//! The discrete-event execution core.
//!
//! One engine instance replays one job against one event trace under
//! one [`Policy`]. The core owns only mechanics — time and segment
//! accounting, the fault & prediction stream plumbing, outcome
//! bookkeeping; everything strategic (period, trust, window response)
//! is a policy answer (see [`crate::sim::policy`]). The machine
//! alternates *segments* — work, checkpoint, downtime, recovery,
//! migration — and every segment can be cut short by a fault.
//! Prediction handling follows the paper's algorithms:
//!
//! * a prediction becomes known at `avail = t0 − lead`; the trust
//!   decision ([`Policy::trust`]) is drawn immediately;
//! * a trusted prediction schedules a proactive action: checkpoint
//!   completing right at t0 (Figure 1(a)), or — when a regular
//!   checkpoint runs past `t0 − C` — extra work up to t0 and no extra
//!   checkpoint (Figure 1(b));
//! * at t0 the engine enters the window phase per the policy's
//!   [`ProactiveMode`]: return to regular (`CkptBefore`), work
//!   unprotected to `t0 + I` (`SkipWindow`), or loop proactive
//!   checkpoints of period T_P (`CkptDuring`, Algorithm 1);
//! * regular-mode period accounting (`W_reg`, Algorithm 1 lines 12/15)
//!   survives proactive excursions and resets on faults and regular
//!   checkpoints; whether the *policy* measures its rule on `W_reg` or
//!   on the volatile work is its own business ([`Policy::ckpt_rule`]).
//!
//! Deviations from the idealized analysis (all conservative, see
//! DESIGN.md): faults can strike during checkpoints, recoveries and
//! migrations (the analysis assumes one event per interval); a
//! prediction whose action point falls inside an outage is honored
//! late when the window is still open and dropped otherwise.

use std::collections::VecDeque;

use super::{Outcome, Policy, PolicyCtx, SimConfig};
use crate::rng::Pcg64;
use crate::strategies::{ProactiveMode, StrategySpec};
use crate::trace::{EventSource, Fault, Prediction};

/// Numerical slack on work comparisons (seconds).
const EPS: f64 = 1e-6;

enum Seg {
    Completed,
    Faulted(Fault),
}

/// The replayer core. Owns its configuration (a handful of scalars
/// copied out of [`SimConfig`] plus the [`Policy`] at construction) so
/// a [`crate::sim::SimSession`] can hold one engine across
/// replications and [`Engine::reset`] it — the `pending`/`neutralized`
/// buffers keep their capacity, making the steady state
/// allocation-free.
pub struct Engine<S: EventSource> {
    cfg: SimConfig,
    /// The checkpoint policy (stateless; consulted per planning round).
    policy: Policy,
    source: S,
    rng_trust: Pcg64,

    now: f64,
    /// Work persisted by checkpoints (survives faults).
    saved: f64,
    /// Work since the last persisted state (lost on fault).
    vol: f64,
    /// Regular-mode work accumulated toward the current period.
    w_reg: f64,
    /// Lead the policy needs ahead of t0.
    lead: f64,

    next_fault: Option<Fault>,
    next_pred: Option<Prediction>,
    /// Trusted predictions awaiting their action point, sorted by t0.
    pending: VecDeque<Prediction>,
    /// Fault ids neutralized by completed migrations. A plain vector:
    /// at most a handful of ids are ever in flight, and a linear scan
    /// beats hashing at that size.
    neutralized: Vec<u64>,

    out: Outcome,
}

impl<S: EventSource> Engine<S> {
    /// Engine for a paper [`StrategySpec`] — sugar over
    /// [`Engine::with_policy`] with [`Policy::from_spec`].
    pub fn new(cfg: &SimConfig, spec: &StrategySpec, source: S, trust_seed: u64) -> Self {
        Self::with_policy(cfg, Policy::from_spec(spec, cfg.c), source, trust_seed)
    }

    /// Engine for an arbitrary [`Policy`]. The policy is
    /// [`Policy::sanitized`] first, so a degenerate hand-built one
    /// (boundary <= 0) cannot stall the core in a zero-progress loop.
    pub fn with_policy(cfg: &SimConfig, policy: Policy, source: S, trust_seed: u64) -> Self {
        let policy = policy.sanitized(cfg.c);
        let lead = policy.required_lead(cfg.c);
        Engine {
            cfg: cfg.clone(),
            policy,
            source,
            rng_trust: Pcg64::new(trust_seed, 0x7157),
            now: 0.0,
            saved: 0.0,
            vol: 0.0,
            w_reg: 0.0,
            lead,
            next_fault: None,
            next_pred: None,
            pending: VecDeque::new(),
            neutralized: Vec::new(),
            out: Outcome::default(),
        }
    }

    /// Rewind to time zero for a new replication under the same
    /// configuration and strategy. Buffers keep their capacity; the
    /// trust RNG is re-derived from `trust_seed`, so a reset engine is
    /// bit-identical to a freshly constructed one.
    pub fn reset(&mut self, trust_seed: u64) {
        self.rng_trust = Pcg64::new(trust_seed, 0x7157);
        self.now = 0.0;
        self.saved = 0.0;
        self.vol = 0.0;
        self.w_reg = 0.0;
        self.next_fault = None;
        self.next_pred = None;
        self.pending.clear();
        self.neutralized.clear();
        self.out = Outcome::default();
    }

    /// The event source, e.g. to reset a generator between replications.
    pub fn source_mut(&mut self) -> &mut S {
        &mut self.source
    }

    #[inline]
    fn work_done(&self) -> f64 {
        self.saved + self.vol
    }

    /// Snapshot of the execution state for one policy consultation.
    #[inline]
    fn policy_ctx(&self) -> PolicyCtx {
        PolicyCtx {
            now: self.now,
            vol: self.vol,
            w_reg: self.w_reg,
            n_faults: self.out.n_faults,
            c: self.cfg.c,
        }
    }

    /// Next fault that actually strikes us (skips migrated-away ones).
    fn peek_fault(&mut self) -> Option<&Fault> {
        loop {
            if self.next_fault.is_none() {
                self.next_fault = self.source.next_fault();
            }
            let f = self.next_fault?;
            if let Some(pos) = self.neutralized.iter().position(|&id| id == f.id) {
                self.neutralized.swap_remove(pos);
                self.out.n_faults_avoided += 1;
                self.next_fault = None;
            } else {
                return self.next_fault.as_ref();
            }
        }
    }

    /// Consume and return the next fault if it strikes strictly before `end`.
    fn take_fault_before(&mut self, end: f64) -> Option<Fault> {
        match self.peek_fault() {
            Some(f) if f.t < end => self.next_fault.take(),
            _ => None,
        }
    }

    /// Process all predictions that have become known by `now`.
    fn drain_predictions(&mut self) {
        loop {
            if self.next_pred.is_none() {
                self.next_pred = self.source.next_prediction();
            }
            match &self.next_pred {
                Some(p) if p.avail <= self.now => {
                    let p = self.next_pred.take().unwrap();
                    self.out.n_preds += 1;
                    if p.is_true_positive() {
                        self.out.n_true_preds += 1;
                    }
                    // Replay sources carry the prediction's pre-sampled
                    // trust uniform; live generators return None and the
                    // engine draws from its own per-replication stream.
                    // Either way the k-th prediction sees the k-th
                    // uniform of the same sequence (rng::trust_seed).
                    let trusted = match self.source.next_trust_uniform() {
                        Some(u) => self.policy.trust_with(u),
                        None => self.policy.trust(&mut self.rng_trust),
                    };
                    if trusted && p.t_end() > self.now {
                        self.out.n_trusted += 1;
                        let pos = self
                            .pending
                            .iter()
                            .position(|q| q.t0 > p.t0)
                            .unwrap_or(self.pending.len());
                        self.pending.insert(pos, p);
                    }
                }
                _ => return,
            }
        }
    }

    /// Work until `end` (absolute time). Returns Faulted if a fault cut
    /// the segment short (fault effects NOT yet applied).
    fn work_until(&mut self, end: f64, count_reg: bool) -> Seg {
        debug_assert!(end >= self.now - 1e-9);
        self.out.n_segments += 1;
        if let Some(f) = self.take_fault_before(end) {
            let elapsed = (f.t - self.now).max(0.0);
            self.vol += elapsed;
            if count_reg {
                self.w_reg += elapsed;
            }
            self.now = f.t;
            return Seg::Faulted(f);
        }
        let elapsed = end - self.now;
        self.vol += elapsed;
        if count_reg {
            self.w_reg += elapsed;
        }
        self.now = end;
        Seg::Completed
    }

    /// A non-working segment (checkpoint, downtime, recovery, migration).
    fn passive(&mut self, duration: f64) -> Seg {
        self.out.n_segments += 1;
        let end = self.now + duration;
        if let Some(f) = self.take_fault_before(end) {
            self.now = f.t;
            return Seg::Faulted(f);
        }
        self.now = end;
        Seg::Completed
    }

    /// Take a checkpoint; on success the volatile work is persisted.
    /// Regular checkpoints close the period (reset `w_reg`); proactive
    /// ones do not (Algorithm 1 keeps W_reg across the excursion).
    fn checkpoint(&mut self, proactive: bool) -> Seg {
        match self.passive(self.cfg.c) {
            Seg::Faulted(f) => Seg::Faulted(f),
            Seg::Completed => {
                self.saved += self.vol;
                self.vol = 0.0;
                if proactive {
                    self.out.n_proactive_ckpts += 1;
                } else {
                    self.out.n_ckpts += 1;
                    self.w_reg = 0.0;
                }
                Seg::Completed
            }
        }
    }

    /// Apply a fault: lose volatile work, run downtime + recovery
    /// (themselves interruptible by further faults), restart the period.
    fn handle_fault(&mut self, mut fault: Fault) {
        loop {
            self.out.n_faults += 1;
            if !fault.predicted {
                self.out.n_faults_unpredicted += 1;
            }
            self.out.lost_work += self.vol;
            self.now = fault.t;
            self.vol = 0.0;
            self.w_reg = 0.0;
            match self.passive(self.cfg.d) {
                Seg::Faulted(f) => {
                    fault = f;
                    continue;
                }
                Seg::Completed => {}
            }
            match self.passive(self.cfg.r) {
                Seg::Faulted(f) => {
                    fault = f;
                    continue;
                }
                Seg::Completed => {}
            }
            break;
        }
        // Predictions whose window already closed are moot now.
        let now = self.now;
        self.pending.retain(|p| p.t_end() > now);
    }

    /// Execute the proactive response to a trusted prediction whose
    /// action point has arrived. Any fault inside aborts the response.
    fn handle_proactive(&mut self, p: Prediction) {
        match self.policy.window_action() {
            ProactiveMode::Ignore => {}
            ProactiveMode::Migrate { m } => self.proactive_migrate(p, m),
            ProactiveMode::CkptBefore | ProactiveMode::SkipWindow | ProactiveMode::CkptDuring { .. } => {
                self.proactive_ckpt_flow(p)
            }
        }
    }

    fn proactive_ckpt_flow(&mut self, p: Prediction) {
        // Pre-window: checkpoint completing right at t0 when there is
        // room (Fig. 1a); otherwise extra work up to t0 (Fig. 1b) —
        // including the case where an outage delayed us past t0 − C.
        let ckpt_start = p.t0 - self.cfg.c;
        if self.now <= ckpt_start {
            if self.now < ckpt_start {
                let end = ckpt_start.min(self.now + self.remaining_work());
                match self.work_until(end, true) {
                    Seg::Faulted(f) => return self.handle_fault(f),
                    Seg::Completed => {}
                }
                if self.remaining_work() <= EPS {
                    return;
                }
            }
            if self.vol > 0.0 {
                match self.checkpoint(true) {
                    Seg::Faulted(f) => return self.handle_fault(f),
                    Seg::Completed => {}
                }
            } else {
                // State already persisted; skip the redundant checkpoint
                // and work through the slot instead.
                let end = p.t0.min(self.now + self.remaining_work());
                match self.work_until(end, true) {
                    Seg::Faulted(f) => return self.handle_fault(f),
                    Seg::Completed => {}
                }
                if self.remaining_work() <= EPS {
                    return;
                }
            }
        } else if self.now < p.t0 {
            let end = p.t0.min(self.now + self.remaining_work());
            match self.work_until(end, true) {
                Seg::Faulted(f) => return self.handle_fault(f),
                Seg::Completed => {}
            }
            if self.remaining_work() <= EPS {
                return;
            }
        }
        if self.now >= p.t_end() && p.window > 0.0 {
            return; // window passed entirely during an outage
        }
        // Window phase.
        match self.policy.window_action() {
            ProactiveMode::CkptBefore => {} // back to regular mode at once
            ProactiveMode::SkipWindow => {
                // Work unprotected through the window; the interrupted
                // regular period resumes at t0 + I (work here does not
                // advance W_reg — it belongs to the proactive mode).
                let end = p.t_end().min(self.now + self.remaining_work());
                if end > self.now {
                    if let Seg::Faulted(f) = self.work_until(end, false) {
                        self.handle_fault(f);
                    }
                }
            }
            ProactiveMode::CkptDuring { t_p } => {
                let t_p = t_p.max(self.cfg.c + 1.0);
                let t_end = p.t_end();
                // Algorithm 1 lines 17-18: work T_P − C, checkpoint, until
                // the window closes (T_P divides I by construction).
                while self.now < t_end - EPS {
                    let slice_end =
                        (self.now + (t_p - self.cfg.c)).min(t_end).min(self.now + self.remaining_work());
                    if slice_end > self.now {
                        match self.work_until(slice_end, false) {
                            Seg::Faulted(f) => return self.handle_fault(f),
                            Seg::Completed => {}
                        }
                    }
                    if self.remaining_work() <= EPS {
                        return; // job finished inside the window
                    }
                    if self.now >= t_end - EPS {
                        break; // window closes; trailing ckpt aligns with it
                    }
                    match self.checkpoint(true) {
                        Seg::Faulted(f) => return self.handle_fault(f),
                        Seg::Completed => {}
                    }
                }
            }
            _ => unreachable!(),
        }
    }

    fn proactive_migrate(&mut self, p: Prediction, m: f64) {
        let start = p.t0 - m;
        if self.now > start {
            return; // cannot complete before the predicted date: abandon
        }
        if self.now < start {
            let end = start.min(self.now + self.remaining_work());
            match self.work_until(end, true) {
                Seg::Faulted(f) => return self.handle_fault(f),
                Seg::Completed => {}
            }
            if self.remaining_work() <= EPS {
                return;
            }
        }
        // Live migration: state (volatile work) moves with the task.
        match self.passive(m) {
            Seg::Faulted(f) => self.handle_fault(f),
            Seg::Completed => {
                self.out.n_migrations += 1;
                if let Some(id) = p.fault_id {
                    // The fault will strike the abandoned node, not us.
                    if self.next_fault.as_ref().map(|f| f.id) == Some(id) {
                        self.next_fault = None;
                        self.out.n_faults_avoided += 1;
                    } else {
                        self.neutralized.push(id);
                    }
                }
            }
        }
    }

    #[inline]
    fn remaining_work(&self) -> f64 {
        (self.cfg.work - self.work_done()).max(0.0)
    }

    /// Run to completion (or the makespan guard).
    pub fn run(mut self) -> Outcome {
        self.run_to_completion()
    }

    /// In-place variant for session reuse: runs the current replication
    /// and hands the outcome out, leaving the engine ready for
    /// [`Engine::reset`]. No allocations beyond buffer growth.
    pub(crate) fn run_to_completion(&mut self) -> Outcome {
        loop {
            if self.remaining_work() <= EPS {
                self.out.completed = true;
                break;
            }
            if self.now > self.cfg.max_makespan {
                self.out.completed = false;
                break;
            }
            self.drain_predictions();

            // Proactive action due?
            if let Some(p) = self.pending.front().copied() {
                let start = (p.t0 - self.lead).max(0.0);
                if start <= self.now {
                    self.pending.pop_front();
                    self.handle_proactive(p);
                    continue;
                }
            }

            // Regular checkpoint due? (Q1: the policy's rule, measured
            // against the core's accounting.)
            let (measured, boundary) = self.policy.ckpt_rule(&self.policy_ctx());
            if measured >= boundary - EPS {
                if self.vol > 0.0 {
                    if let Seg::Faulted(f) = self.checkpoint(false) {
                        self.handle_fault(f);
                    }
                } else {
                    self.w_reg = 0.0; // state already persisted
                }
                continue;
            }

            // Plan the next work slice, capped at the policy's rule.
            let mut end = self.now + self.remaining_work();
            end = end.min(self.now + (boundary - measured).max(0.0));
            if let Some(p) = self.pending.front() {
                end = end.min((p.t0 - self.lead).max(self.now));
            }
            // Cut at the next prediction-availability so the trust
            // decision happens at the right simulated time.
            if self.next_pred.is_none() {
                self.next_pred = self.source.next_prediction();
            }
            if let Some(pr) = &self.next_pred {
                if pr.avail > self.now {
                    end = end.min(pr.avail);
                }
            }
            if end <= self.now + 1e-9 {
                // Defensive: only reachable through degenerate pending
                // entries; drop the blocker and move on.
                self.pending.pop_front();
                continue;
            }
            if let Seg::Faulted(f) = self.work_until(end, true) {
                self.handle_fault(f);
            }
        }
        self.out.makespan = self.now;
        self.out.work = self.work_done().min(self.cfg.work);
        std::mem::take(&mut self.out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::VecSource;

    fn cfg(work: f64) -> SimConfig {
        SimConfig { work, c: 10.0, d: 2.0, r: 5.0, max_makespan: 1e12 }
    }

    fn spec(t_r: f64, proactive: ProactiveMode) -> StrategySpec {
        let q = if matches!(proactive, ProactiveMode::Ignore) { 0.0 } else { 1.0 };
        StrategySpec { name: "test".into(), t_r, q, proactive }
    }

    fn run(cfg: &SimConfig, spec: &StrategySpec, faults: Vec<Fault>, preds: Vec<Prediction>) -> Outcome {
        Engine::new(cfg, spec, VecSource::new(faults, preds), 7).run()
    }

    #[test]
    fn fault_free_periodic() {
        // W = 300, T = 110 (work 100 per period, ckpt 10): two full
        // periods with checkpoints + final 100 work, no trailing ckpt.
        let c = cfg(300.0);
        let s = spec(110.0, ProactiveMode::Ignore);
        let o = run(&c, &s, vec![], vec![]);
        assert!(o.completed);
        assert_eq!(o.n_ckpts, 2);
        assert!((o.makespan - 320.0).abs() < 1e-6, "makespan {}", o.makespan);
        assert!((o.waste() - 20.0 / 320.0).abs() < 1e-9);
    }

    #[test]
    fn single_fault_loses_volatile_work() {
        // Fault at t=50: 50 work lost, downtime 2 + recovery 5, then
        // the 300 work redone from scratch.
        let c = cfg(300.0);
        let s = spec(1e6, ProactiveMode::Ignore); // no intermediate ckpt
        let o = run(&c, &s, vec![Fault::unpredicted(50.0, 0)], vec![]);
        assert!(o.completed);
        assert_eq!(o.n_faults, 1);
        assert!((o.lost_work - 50.0).abs() < 1e-9);
        assert!((o.makespan - (50.0 + 2.0 + 5.0 + 300.0)).abs() < 1e-6);
    }

    #[test]
    fn fault_after_checkpoint_resumes_from_checkpoint() {
        // T = 110: ckpt completes at 110 (100 saved). Fault at 115:
        // lose 5 volatile; resume with 200 left.
        let c = cfg(300.0);
        let s = spec(110.0, ProactiveMode::Ignore);
        let o = run(&c, &s, vec![Fault::unpredicted(115.0, 0)], vec![]);
        assert!(o.completed);
        assert!((o.lost_work - 5.0).abs() < 1e-9);
        // 115 + 7 (D+R) + 100 work + 10 ckpt + 100 work + 10 ckpt...
        // after recovery at 122: 200 work left, period restarts:
        // work 100, ckpt -> 232; work 100 -> 332 done.
        assert!((o.makespan - 332.0).abs() < 1e-6, "makespan {}", o.makespan);
        assert_eq!(o.n_ckpts, 2);
    }

    #[test]
    fn fault_during_checkpoint_destroys_it() {
        // T = 110, ckpt spans [100, 110]; fault at 105 → all 100
        // volatile lost.
        let c = cfg(300.0);
        let s = spec(110.0, ProactiveMode::Ignore);
        let o = run(&c, &s, vec![Fault::unpredicted(105.0, 0)], vec![]);
        assert!(o.completed);
        assert!((o.lost_work - 100.0).abs() < 1e-9);
        // After the fault all 300 work remains: work/ckpt, work/ckpt, work.
        assert_eq!(o.n_ckpts, 2);
    }

    #[test]
    fn fault_during_recovery_restarts_it() {
        let c = cfg(100.0);
        let s = spec(1e6, ProactiveMode::Ignore);
        // First fault at 10; recovery spans [12, 17]; second at 14.
        let o = run(
            &c,
            &s,
            vec![Fault::unpredicted(10.0, 0), Fault::unpredicted(14.0, 1)],
            vec![],
        );
        assert!(o.completed);
        assert_eq!(o.n_faults, 2);
        // 14 + 2 + 5 + 100.
        assert!((o.makespan - 121.0).abs() < 1e-6, "makespan {}", o.makespan);
    }

    #[test]
    fn exact_prediction_saves_work() {
        // Fault at 500 predicted exactly; proactive ckpt spans
        // [490, 500]; only D+R is lost.
        let c = cfg(1000.0);
        let s = spec(1e6, ProactiveMode::CkptBefore);
        let o = run(
            &c,
            &s,
            vec![Fault::predicted(500.0, 0)],
            vec![Prediction::exact(500.0, 10.0, Some(0))],
        );
        assert!(o.completed);
        assert_eq!(o.n_proactive_ckpts, 1);
        assert!((o.lost_work - 0.0).abs() < 1e-9);
        // 500 (work+ckpt) + 7 (D+R) + 510 remaining work = 1017.
        assert!((o.makespan - 1017.0).abs() < 1e-6, "makespan {}", o.makespan);
    }

    #[test]
    fn untrusted_prediction_is_ignored() {
        let c = cfg(1000.0);
        let mut s = spec(1e6, ProactiveMode::CkptBefore);
        s.q = 0.0;
        let o = run(
            &c,
            &s,
            vec![Fault::predicted(500.0, 0)],
            vec![Prediction::exact(500.0, 10.0, Some(0))],
        );
        assert!(o.completed);
        assert_eq!(o.n_proactive_ckpts, 0);
        assert!((o.lost_work - 500.0).abs() < 1e-9);
        assert_eq!(o.n_trusted, 0);
        assert_eq!(o.n_preds, 1);
    }

    #[test]
    fn false_prediction_costs_one_checkpoint() {
        let c = cfg(1000.0);
        let s = spec(1e6, ProactiveMode::CkptBefore);
        let o = run(&c, &s, vec![], vec![Prediction::exact(500.0, 10.0, None)]);
        assert!(o.completed);
        assert_eq!(o.n_proactive_ckpts, 1);
        assert!((o.makespan - 1010.0).abs() < 1e-6);
        assert_eq!(o.n_faults, 0);
    }

    #[test]
    fn window_skip_mode_waits_out_the_window() {
        // Window [500, 600], fault at 580. SkipWindow: ckpt [490,500],
        // work through window, fault at 580 loses the 80 done since t0.
        let c = cfg(1000.0);
        let s = spec(1e6, ProactiveMode::SkipWindow);
        let o = run(
            &c,
            &s,
            vec![Fault::predicted(580.0, 0)],
            vec![Prediction::windowed(500.0, 100.0, 10.0, Some(0))],
        );
        assert!(o.completed);
        assert!((o.lost_work - 80.0).abs() < 1e-9, "lost {}", o.lost_work);
        // 580 + 7 + remaining (1000 − 490) = 1097.
        assert!((o.makespan - 1097.0).abs() < 1e-6, "makespan {}", o.makespan);
    }

    #[test]
    fn window_ckpt_during_bounds_loss_to_tp() {
        // Window [500, 700], T_P = 110 (work 100 + ckpt 10).
        // Fault at 695: in-window ckpts at [600,610]; loss = work in
        // (610, 695) = 85.
        let c = cfg(2000.0);
        let s = spec(1e6, ProactiveMode::CkptDuring { t_p: 110.0 });
        let o = run(
            &c,
            &s,
            vec![Fault::predicted(695.0, 0)],
            vec![Prediction::windowed(500.0, 200.0, 10.0, Some(0))],
        );
        assert!(o.completed);
        assert_eq!(o.n_proactive_ckpts, 2); // pre-window + one inside
        assert!((o.lost_work - 85.0).abs() < 1e-9, "lost {}", o.lost_work);
    }

    #[test]
    fn migration_avoids_predicted_fault() {
        let c = cfg(1000.0);
        let s = spec(1e6, ProactiveMode::Migrate { m: 20.0 });
        let o = run(
            &c,
            &s,
            vec![Fault::predicted(500.0, 0)],
            vec![Prediction::exact(500.0, 20.0, Some(0))],
        );
        assert!(o.completed);
        assert_eq!(o.n_migrations, 1);
        assert_eq!(o.n_faults, 0);
        assert_eq!(o.n_faults_avoided, 1);
        // Only the 20 s migration is lost: 1020.
        assert!((o.makespan - 1020.0).abs() < 1e-6, "makespan {}", o.makespan);
        assert!((o.lost_work - 0.0).abs() < 1e-9);
    }

    #[test]
    fn false_prediction_migration_costs_m() {
        let c = cfg(1000.0);
        let s = spec(1e6, ProactiveMode::Migrate { m: 20.0 });
        let o = run(&c, &s, vec![], vec![Prediction::exact(500.0, 20.0, None)]);
        assert!(o.completed);
        assert!((o.makespan - 1020.0).abs() < 1e-6);
    }

    #[test]
    fn prediction_too_late_for_migration_is_abandoned() {
        // avail/lead allows ckpt (10) but not migration (100): the
        // engine cannot start at t0 − m < avail-time ⇒ fault strikes.
        let c = cfg(1000.0);
        let s = spec(1e6, ProactiveMode::Migrate { m: 100.0 });
        let o = run(
            &c,
            &s,
            vec![Fault::predicted(50.0, 0)],
            vec![Prediction::exact(50.0, 100.0, Some(0))], // avail < 0 → clamped late
        );
        assert!(o.completed);
        assert_eq!(o.n_migrations, 0);
        assert_eq!(o.n_faults, 1);
    }

    #[test]
    fn fig1b_no_room_for_extra_checkpoint() {
        // Regular T = 110 ⇒ ckpt spans [100, 110]. Prediction for
        // t0 = 115 becomes known at 105 (mid-checkpoint). The regular
        // checkpoint finishes at 110; vol = 0 afterwards ⇒ no extra
        // proactive ckpt; work [110, 115] runs at risk (Fig. 1b).
        let c = cfg(300.0);
        let s = spec(110.0, ProactiveMode::CkptBefore);
        let o = run(
            &c,
            &s,
            vec![Fault::predicted(115.0, 0)],
            vec![Prediction::exact(115.0, 10.0, Some(0))],
        );
        assert!(o.completed);
        assert_eq!(o.n_proactive_ckpts, 0);
        assert_eq!(o.n_ckpts, 2); // the [100,110] one + one later
        assert!((o.lost_work - 5.0).abs() < 1e-9, "lost {}", o.lost_work);
    }

    #[test]
    fn job_completes_mid_window() {
        // Job finishes inside the prediction window — engine must stop.
        let c = cfg(520.0);
        let s = spec(1e6, ProactiveMode::SkipWindow);
        let o = run(&c, &s, vec![], vec![Prediction::windowed(500.0, 200.0, 10.0, None)]);
        assert!(o.completed);
        // ckpt [490, 500] then 30 remaining work inside window: 530.
        assert!((o.makespan - 530.0).abs() < 1e-6, "makespan {}", o.makespan);
    }

    #[test]
    fn makespan_guard_reports_incomplete() {
        let mut c = cfg(1000.0);
        c.max_makespan = 400.0;
        let s = spec(1e6, ProactiveMode::Ignore);
        // Fault storm: every 100 s, job can never finish.
        let faults: Vec<Fault> =
            (1..2000).map(|i| Fault::unpredicted(i as f64 * 100.0, i as u64)).collect();
        // Never completes 1000 contiguous work.
        let o = run(&c, &s, faults, vec![]);
        assert!(!o.completed);
    }

    fn run_policy(
        cfg: &SimConfig,
        policy: Policy,
        faults: Vec<Fault>,
        preds: Vec<Prediction>,
    ) -> Outcome {
        Engine::with_policy(cfg, policy, VecSource::new(faults, preds), 7).run()
    }

    #[test]
    fn risk_policy_resets_on_proactive_checkpoints() {
        // The rule the old engine could not express: RiskThreshold
        // measures *volatile* work, so a proactive checkpoint restarts
        // its countdown, while fixed-period W_reg accounting keeps
        // counting. One false exact prediction at t0 = 95 (trusted,
        // CkptBefore), W = 250, C = 10, w_star = 100 vs T_R = 110:
        //
        //   risk : work 85, pro-ckpt [85,95], work 100, ckpt [195,205],
        //          work 65 -> 270 (1 regular ckpt);
        //   paper: work 85, pro-ckpt [85,95], work 15 (W_reg hits 100),
        //          ckpt [110,120], work 100, ckpt [220,230], work 50
        //          -> 280 (2 regular ckpts).
        let c = cfg(250.0);
        let risk = Policy::RiskThreshold {
            w_star: 100.0,
            q: 1.0,
            proactive: ProactiveMode::CkptBefore,
        };
        let preds = vec![Prediction::exact(95.0, 10.0, None)];
        let o = run_policy(&c, risk, vec![], preds.clone());
        assert!(o.completed);
        assert_eq!(o.n_proactive_ckpts, 1);
        assert_eq!(o.n_ckpts, 1);
        assert!((o.makespan - 270.0).abs() < 1e-6, "risk makespan {}", o.makespan);

        let paper = spec(110.0, ProactiveMode::CkptBefore);
        let o = run(&c, &paper, vec![], preds);
        assert!(o.completed);
        assert_eq!(o.n_proactive_ckpts, 1);
        assert_eq!(o.n_ckpts, 2);
        assert!((o.makespan - 280.0).abs() < 1e-6, "paper makespan {}", o.makespan);
    }

    #[test]
    fn adaptive_policy_stretches_the_period_while_fault_free() {
        // mu0 = 500, C = 10: the prior period is sqrt(2*500*10) = 100
        // (boundary 90). Fault-free observation grows mu_hat, so by the
        // time W_reg reaches 90 the boundary has moved past it and the
        // W = 95 job finishes without any checkpoint; a fixed T_R = 100
        // pays one.
        let c = cfg(95.0);
        let adaptive = Policy::AdaptivePeriod {
            mu0: 500.0,
            gain: 1.0,
            q: 0.0,
            proactive: ProactiveMode::Ignore,
        };
        let o = run_policy(&c, adaptive, vec![], vec![]);
        assert!(o.completed);
        assert_eq!(o.n_ckpts, 0);
        assert!((o.makespan - 95.0).abs() < 1e-6, "adaptive makespan {}", o.makespan);

        let young = spec(100.0, ProactiveMode::Ignore);
        let o = run(&c, &young, vec![], vec![]);
        assert_eq!(o.n_ckpts, 1);
        assert!((o.makespan - 105.0).abs() < 1e-6, "young makespan {}", o.makespan);
    }

    #[test]
    fn adaptive_policy_tightens_the_period_under_faults() {
        // Same prior, but a fault storm: the observed rate pulls the
        // derived period below the prior, so checkpoints come sooner
        // than the prior's 90-second boundary would place them.
        let c = cfg(300.0);
        let adaptive = Policy::AdaptivePeriod {
            mu0: 500.0,
            gain: 1.0,
            q: 0.0,
            proactive: ProactiveMode::Ignore,
        };
        let faults: Vec<Fault> =
            (1..=8).map(|i| Fault::unpredicted(i as f64 * 40.0, i as u64)).collect();
        let o = run_policy(&c, adaptive, faults, vec![]);
        assert!(o.completed);
        assert_eq!(o.n_faults, 8);
        // After the storm (last fault at 320) the observed MTBF sits
        // near 90 s, so the derived period drops to ~43 s — far below
        // the prior's 100 s — and the 300 s of work pays several
        // checkpoints the fault-free run above never would.
        assert!(o.n_ckpts >= 4, "adapted n_ckpts = {}", o.n_ckpts);
        assert!(o.makespan > 300.0);
    }

    #[test]
    fn degenerate_hand_built_policies_cannot_stall_the_core() {
        // A zero/NaN boundary through the public with_policy entry
        // point must be floored at construction, not spin the loop
        // (the in-tree builders all floor already; this pins the raw
        // enum path).
        let c = cfg(50.0);
        for policy in [
            Policy::Paper { t_r: 0.0, q: 0.0, proactive: ProactiveMode::Ignore },
            Policy::Paper { t_r: f64::NAN, q: 0.0, proactive: ProactiveMode::Ignore },
            Policy::RiskThreshold { w_star: 0.0, q: 1.0, proactive: ProactiveMode::CkptBefore },
            Policy::AdaptivePeriod {
                mu0: f64::NAN,
                gain: 1.0,
                q: 0.0,
                proactive: ProactiveMode::Ignore,
            },
        ] {
            let o = run_policy(&c, policy, vec![], vec![]);
            assert!(o.completed, "{policy:?} stalled");
            assert!(o.makespan >= 50.0);
        }
    }

    #[test]
    fn policy_engine_matches_spec_engine_bit_for_bit() {
        // The refactor contract at the engine level: a spec-built
        // engine and a policy-built engine are the same machine.
        let c = cfg(2000.0);
        for proactive in [
            ProactiveMode::Ignore,
            ProactiveMode::CkptBefore,
            ProactiveMode::SkipWindow,
            ProactiveMode::CkptDuring { t_p: 110.0 },
            ProactiveMode::Migrate { m: 20.0 },
        ] {
            let s = spec(110.0, proactive);
            let faults = vec![Fault::predicted(500.0, 0), Fault::unpredicted(901.0, 1)];
            let preds = vec![Prediction::windowed(500.0, 200.0, 20.0, Some(0))];
            let a = run(&c, &s, faults.clone(), preds.clone());
            let b = run_policy(&c, Policy::from_spec(&s, c.c), faults, preds);
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{proactive:?}");
            assert_eq!(a.n_segments, b.n_segments, "{proactive:?}");
            assert_eq!(a.n_ckpts, b.n_ckpts, "{proactive:?}");
            assert_eq!(a.lost_work.to_bits(), b.lost_work.to_bits(), "{proactive:?}");
        }
    }

    #[test]
    fn work_conservation() {
        // makespan == work + overhead, with overhead = ckpts + faults'
        // D+R + lost work (+ idle): verified via the identity.
        let c = cfg(300.0);
        let s = spec(110.0, ProactiveMode::Ignore);
        let o = run(&c, &s, vec![Fault::unpredicted(115.0, 0)], vec![]);
        let ckpt_time = (o.n_ckpts + o.n_proactive_ckpts) as f64 * c.c;
        let fault_time = o.n_faults as f64 * (c.d + c.r);
        let accounted = ckpt_time + fault_time + o.lost_work;
        assert!(
            (o.overhead() - accounted).abs() < 1e-6,
            "overhead {} vs accounted {accounted}",
            o.overhead()
        );
    }
}
