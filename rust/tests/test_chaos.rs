#![cfg(feature = "chaos")]
//! Deterministic chaos harness: every fault in this suite is injected
//! by an installed [`ckptfp::chaos::ChaosPlan`], so each failure mode
//! reproduces bit-for-bit — no sleeps-and-hope, no random kill signals.
//!
//! The plan registry is process-global, so the tests serialize on one
//! gate and always clear the plan through a drop guard: a failing
//! assertion cannot leak injections into the next test.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use ckptfp::api::{
    wire, ErrorCode, Executor, ExecutorConfig, JobRequest, JobResponse, PlanJob, ServiceClient,
    ServiceStats, SimulateJob,
};
use ckptfp::chaos::{self, Action, ChaosPlan, Point};
use ckptfp::config::{Predictor, Scenario};
use ckptfp::coordinator::{serve, ServiceConfig, ServiceHandle};
use ckptfp::dist::DistSpec;
use ckptfp::model::{Capping, StrategyKind};
use ckptfp::sim::{BatchEngine, BatchRunner, Policy, ReplicationAgg, SimSession};
use ckptfp::strategies::spec_for;
use ckptfp::trace::{ReplaySource, TraceBank};

static GATE: Mutex<()> = Mutex::new(());

/// Holds the inter-test gate and clears the global plan on drop, even
/// when the test body panics.
struct ChaosSession {
    _gate: MutexGuard<'static, ()>,
}

impl Drop for ChaosSession {
    fn drop(&mut self) {
        chaos::reset();
    }
}

fn begin() -> ChaosSession {
    let gate = GATE.lock().unwrap_or_else(|p| p.into_inner());
    chaos::reset();
    ChaosSession { _gate: gate }
}

fn small_scenario() -> Scenario {
    let mut s = Scenario::paper(1 << 16, Predictor::exact(0.85, 0.82));
    s.fault_dist = DistSpec::Exp;
    s.work = 2.0e5;
    s
}

fn start_service(exec_cfg: ExecutorConfig, svc_cfg: ServiceConfig) -> (ServiceHandle, String) {
    let handle = serve(Executor::new(exec_cfg), svc_cfg).unwrap();
    let addr = handle.addr.to_string();
    (handle, addr)
}

fn local_cfg() -> ServiceConfig {
    ServiceConfig { addr: "127.0.0.1:0".into(), ..Default::default() }
}

/// Raw line-per-request connection, for byte-exact assertions and for
/// driving several requests down one TCP stream.
struct RawConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl RawConn {
    fn connect(addr: &str) -> RawConn {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        RawConn { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn send_line(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
    }

    fn recv_line(&mut self) -> String {
        let mut out = String::new();
        self.reader.read_line(&mut out).unwrap();
        assert!(!out.is_empty(), "server closed the connection");
        out.trim_end_matches('\n').to_string()
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.send_line(line);
        self.recv_line()
    }
}

/// Poll `stats` over fresh connections until one gets through; sheds
/// from a still-draining gate are retried, anything else is fatal.
fn stats_eventually(addr: &str) -> ServiceStats {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let mut client = ServiceClient::connect(addr).unwrap();
        match client.stats() {
            Ok(s) => return s,
            Err(_) if Instant::now() < deadline => std::thread::sleep(Duration::from_millis(50)),
            Err(e) => panic!("stats never got through: {e:#}"),
        }
    }
}

fn expect_error(line: &str) -> ckptfp::api::ApiError {
    match wire::decode_response(line).unwrap() {
        JobResponse::Error(e) => e,
        other => panic!("expected an error response, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Clean path: the chaos build with zero injections is the plain build
// ---------------------------------------------------------------------------

#[test]
fn zero_injection_chaos_build_matches_plain_responses() {
    let _s = begin(); // no plan installed: every hook is a no-op
    let exec_cfg =
        ExecutorConfig { workers: 2, reps_default: 4, ..Default::default() };
    let (handle, addr) = start_service(exec_cfg.clone(), local_cfg());
    let local = Executor::new(exec_cfg);
    let mut conn = RawConn::connect(&addr);

    // Deterministic jobs pin exact response bytes against the
    // in-process encoding.
    for req in [JobRequest::Ping, JobRequest::Plan(PlanJob::new(small_scenario()))] {
        let served = conn.roundtrip(&wire::encode_request(&req));
        let expect = wire::encode_response(&local.execute(&req), false);
        assert_eq!(served, expect, "served bytes must match the in-process encoding");
    }

    // Simulate carries wall-clock `sim_seconds`; compare everything
    // else bit-for-bit.
    let mut job = SimulateJob::new(small_scenario(), StrategyKind::Young);
    job.reps = 6;
    job.workers = Some(2);
    let served = conn.roundtrip(&wire::encode_request(&JobRequest::Simulate(job.clone())));
    let mut served = match wire::decode_response(&served).unwrap() {
        JobResponse::Simulate(r) => r,
        other => panic!("expected a simulate response, got {other:?}"),
    };
    let mut expect = match local.execute(&JobRequest::Simulate(job)) {
        JobResponse::Simulate(r) => r,
        other => panic!("expected a simulate response, got {other:?}"),
    };
    served.sim_seconds = 0.0;
    expect.sim_seconds = 0.0;
    assert_eq!(served, expect);

    assert!(chaos::fired().is_empty(), "nothing may fire without a plan");
    drop(conn);
    handle.stop();
}

// ---------------------------------------------------------------------------
// Admission control
// ---------------------------------------------------------------------------

#[test]
fn connection_burst_past_the_gate_is_shed_not_hung() {
    let _s = begin();
    let (handle, addr) = start_service(
        ExecutorConfig { workers: 1, ..Default::default() },
        ServiceConfig { addr: "127.0.0.1:0".into(), max_conns: 1, ..Default::default() },
    );

    // The ping proves connection A owns the only slot before B arrives.
    let ping = wire::encode_request(&JobRequest::Ping);
    let mut first = RawConn::connect(&addr);
    assert!(first.roundtrip(&ping).contains("\"pong\""));

    let started = Instant::now();
    let mut second = RawConn::connect(&addr);
    let err = expect_error(&second.roundtrip(&ping));
    assert!(
        started.elapsed() < Duration::from_secs(10),
        "shed must be prompt, took {:?}",
        started.elapsed()
    );
    assert_eq!(err.code, ErrorCode::Overloaded);
    let hint = err.retry_after_ms.expect("overloaded must carry a retry hint");
    assert!(hint > 0, "retry_after_ms = {hint}");

    // Closing A frees the slot; stats (its own connection) gets
    // through once the conn thread notices, and counts the shed.
    drop(second);
    drop(first);
    let stats = stats_eventually(&addr);
    assert!(stats.rejected_overloaded >= 1, "stats: {stats:?}");
    handle.stop();
}

// ---------------------------------------------------------------------------
// Panic isolation
// ---------------------------------------------------------------------------

#[test]
fn injected_worker_panic_is_contained_to_one_response() {
    let _s = begin();
    let (handle, addr) = start_service(ExecutorConfig::default(), local_cfg());
    chaos::install(ChaosPlan::new().at(Point::PoolTask, &[0], Action::Panic));

    let mut job = SimulateJob::new(small_scenario(), StrategyKind::Young);
    job.reps = 2;
    job.workers = Some(1);
    let line = wire::encode_request(&JobRequest::Simulate(job));
    let mut conn = RawConn::connect(&addr);

    // Hit 0 panics inside the replication worker: the client sees a
    // structured internal error, not a dropped connection.
    let err = expect_error(&conn.roundtrip(&line));
    assert_eq!(err.code, ErrorCode::Internal);
    assert!(err.message.contains("panic"), "{}", err.message);

    // The very same connection serves the identical job next; later
    // hits have no scheduled action.
    match wire::decode_response(&conn.roundtrip(&line)).unwrap() {
        JobResponse::Simulate(r) => assert_eq!(r.reps, 2),
        other => panic!("expected success after the contained panic, got {other:?}"),
    }
    assert!(
        chaos::fired().iter().any(|(p, _, a)| *p == Point::PoolTask && *a == Action::Panic),
        "the injection must be on record: {:?}",
        chaos::fired()
    );
    chaos::reset();

    let stats = stats_eventually(&addr);
    assert_eq!(stats.panics_contained, 1, "stats: {stats:?}");
    drop(conn);
    handle.stop();
}

#[test]
fn injected_panic_under_simultaneous_load_spares_the_neighbors() {
    let _s = begin();
    let (handle, addr) = start_service(
        ExecutorConfig { workers: 2, reps_default: 4, ..Default::default() },
        local_cfg(),
    );
    // Exactly one pool task panics; five concurrent clients race for
    // it. Whoever draws the poisoned task gets a structured internal
    // error — everyone else's job completes untouched.
    chaos::install(ChaosPlan::new().at(Point::PoolTask, &[0], Action::Panic));

    let n_clients = 5;
    let outcomes: Vec<bool> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..n_clients {
            let addr = addr.clone();
            handles.push(scope.spawn(move || {
                let mut job = SimulateJob::new(small_scenario(), StrategyKind::Young);
                job.reps = 2;
                job.workers = Some(1);
                let mut conn = RawConn::connect(&addr);
                let resp =
                    conn.roundtrip(&wire::encode_request(&JobRequest::Simulate(job)));
                match wire::decode_response(&resp).unwrap() {
                    JobResponse::Simulate(r) => {
                        assert_eq!(r.reps, 2, "neighbor's job truncated");
                        true
                    }
                    JobResponse::Error(e) => {
                        assert_eq!(e.code, ErrorCode::Internal, "{e:?}");
                        assert!(e.message.contains("panic"), "{}", e.message);
                        false
                    }
                    other => panic!("unexpected response: {other:?}"),
                }
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let ok = outcomes.iter().filter(|&&b| b).count();
    assert_eq!(ok, n_clients - 1, "exactly one client absorbs the panic: {outcomes:?}");
    chaos::reset();

    let stats = stats_eventually(&addr);
    assert_eq!(stats.panics_contained, 1, "stats: {stats:?}");
    handle.stop();
}

// ---------------------------------------------------------------------------
// Deadlines
// ---------------------------------------------------------------------------

#[test]
fn oversized_simulate_trips_the_deadline_within_twice_the_budget() {
    let _s = begin();
    let budget = Duration::from_millis(500);
    let (handle, addr) = start_service(
        ExecutorConfig { workers: 2, deadline: Some(budget), ..Default::default() },
        local_cfg(),
    );
    let mut job = SimulateJob::new(small_scenario(), StrategyKind::Young);
    job.reps = 1_000_000; // far beyond a 500 ms budget, under the reps cap
    job.workers = Some(2);

    let mut conn = RawConn::connect(&addr);
    let started = Instant::now();
    let err = expect_error(&conn.roundtrip(&wire::encode_request(&JobRequest::Simulate(job))));
    let elapsed = started.elapsed();

    assert_eq!(err.code, ErrorCode::DeadlineExceeded);
    assert!(err.message.contains("before the deadline"), "{}", err.message);
    assert!(err.message.contains("of 1000000"), "{}", err.message);
    assert!(elapsed < budget * 2, "replied in {elapsed:?} against a {budget:?} budget");

    let stats = stats_eventually(&addr);
    assert_eq!(stats.deadline_exceeded, 1, "stats: {stats:?}");
    drop(conn);
    handle.stop();
}

// ---------------------------------------------------------------------------
// Graceful drain
// ---------------------------------------------------------------------------

#[test]
fn stop_drains_the_in_flight_job() {
    let _s = begin();
    let (handle, addr) = start_service(
        ExecutorConfig { workers: 2, ..Default::default() },
        ServiceConfig {
            addr: "127.0.0.1:0".into(),
            drain: Duration::from_secs(30),
            ..Default::default()
        },
    );
    let mut job = SimulateJob::new(small_scenario(), StrategyKind::Young);
    job.reps = 2000; // long enough that stop() lands mid-job
    job.workers = Some(2);

    let mut conn = RawConn::connect(&addr);
    conn.send_line(&wire::encode_request(&JobRequest::Simulate(job)));
    // Give the service time to pick the job up, then stop underneath it.
    std::thread::sleep(Duration::from_millis(150));
    let stopper = std::thread::spawn(move || handle.stop());

    // Drain semantics: the in-flight response is still delivered whole.
    match wire::decode_response(&conn.recv_line()).unwrap() {
        JobResponse::Simulate(r) => assert_eq!(r.reps, 2000),
        other => panic!("drain must deliver the in-flight response, got {other:?}"),
    }
    stopper.join().unwrap();
}

// ---------------------------------------------------------------------------
// Wire-level injections
// ---------------------------------------------------------------------------

#[test]
fn torn_and_ballooned_lines_err_but_the_connection_survives() {
    let _s = begin();
    let (handle, addr) = start_service(ExecutorConfig::default(), local_cfg());
    chaos::install(
        ChaosPlan::new()
            .at(Point::ServiceRead, &[0], Action::TornLine)
            .at(Point::ServiceRead, &[1], Action::OversizedLine),
    );
    let ping = wire::encode_request(&JobRequest::Ping);
    let mut conn = RawConn::connect(&addr);

    // Hit 0: the line is torn mid-JSON.
    let err = expect_error(&conn.roundtrip(&ping));
    assert_eq!(err.code, ErrorCode::InvalidJson);

    // Hit 1: the line balloons past the wire limit.
    let err = expect_error(&conn.roundtrip(&ping));
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("exceeds"), "{}", err.message);

    // Hit 2: no scheduled action — the same connection still answers.
    match wire::decode_response(&conn.roundtrip(&ping)).unwrap() {
        JobResponse::Pong => {}
        other => panic!("expected pong after the injections, got {other:?}"),
    }
    assert_eq!(chaos::fired().len(), 2, "{:?}", chaos::fired());
    drop(conn);
    handle.stop();
}

// ---------------------------------------------------------------------------
// Trace-bank injections (in process)
// ---------------------------------------------------------------------------

#[test]
fn forced_bank_decline_and_replay_underrun_take_the_fallback_paths() {
    let _s = begin();
    let s = small_scenario();
    let lead = s.platform.c;

    // Sanity: this scenario normally gets a bank.
    let bank = TraceBank::try_build(&s, lead, 4).unwrap().expect("bank fits the budget");
    assert_eq!(bank.reps(), 4);

    // A forced decline looks exactly like the over-budget path: the
    // caller gets Ok(None) and must keep live sessions.
    chaos::install(ChaosPlan::new().at(Point::BankReserve, &[0], Action::DeclineBank));
    assert!(TraceBank::try_reserve(&s, lead, 4).unwrap().is_none());
    // Hit 1 has no action: the same call succeeds again.
    assert!(TraceBank::try_reserve(&s, lead, 4).unwrap().is_some());

    // A forced underrun reports a missing span even though rep 0 is
    // materialized; the consumer's fall-back-to-live contract applies.
    chaos::install(ChaosPlan::new().at(Point::BankReplay, &[0], Action::Underrun));
    let mut source = ReplaySource::new(Arc::new(bank));
    assert!(!source.reset(0), "hit 0 must be forced to underrun");
    assert!(source.underrun());
    assert!(source.reset(0), "hit 1 is clean: the span is really there");
    assert!(!source.underrun());

    let fired = chaos::fired();
    assert!(
        fired.iter().any(|(p, _, a)| *p == Point::BankReplay && *a == Action::Underrun),
        "{fired:?}"
    );
}

#[test]
fn forced_underrun_inside_a_lockstep_chunk_falls_back_to_the_live_lane() {
    let _s = begin();
    let s = small_scenario();
    let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    let policy = Policy::from_spec(&spec, s.platform.c);
    let lead = policy.required_lead(s.platform.c);
    let bank = Arc::new(TraceBank::try_build(&s, lead, 4).unwrap().expect("bank fits the budget"));

    // Bank-free live reference: replay is pinned bit-identical to live
    // generation, so a lane forced off the bank must land on exactly
    // these numbers.
    let mut live = ReplicationAgg::default();
    let mut session = SimSession::from_policy(&s, policy).unwrap();
    for rep in 0..4 {
        live.push(&session.run(rep));
    }

    // Hit 1 is the chunk's second phase-1 cursor reset: lane 1 is
    // forced to underrun even though rep 1 is fully materialized,
    // exercising the *mid-chunk* fallback (lanes 0, 2, 3 stay on the
    // bank around it).
    chaos::install(ChaosPlan::new().at(Point::BankReplay, &[1], Action::Underrun));
    let before = ckptfp::sim::batch::counters();
    let mut agg = ReplicationAgg::default();
    let mut runner = BatchRunner::Lockstep(BatchEngine::new(bank, &s, policy, 4).unwrap());
    runner.run_reps(&[0, 1, 2, 3], |_, out| agg.push(out));
    let after = ckptfp::sim::batch::counters();

    assert_eq!(agg.n_reps, live.n_reps);
    assert_eq!(agg.n_completed, live.n_completed);
    assert_eq!(agg.n_faults, live.n_faults);
    assert_eq!(agg.n_ckpts, live.n_ckpts);
    assert_eq!(agg.n_segments, live.n_segments);
    assert_eq!(agg.lost_work.to_bits(), live.lost_work.to_bits());
    assert_eq!(agg.waste.mean().to_bits(), live.waste.mean().to_bits());
    assert_eq!(agg.makespan.mean().to_bits(), live.makespan.mean().to_bits());

    assert!(after.lanes_run >= before.lanes_run + 4, "4 lanes ran: {after:?}");
    assert!(after.lane_fallbacks >= before.lane_fallbacks + 1, "lane 1 fell back: {after:?}");
    let fired = chaos::fired();
    assert!(
        fired.iter().any(|(p, hit, a)| {
            *p == Point::BankReplay && *hit == 1 && *a == Action::Underrun
        }),
        "{fired:?}"
    );
}
