//! Malformed-wire corpus: hostile request lines against both the
//! decoder (in process) and a live service (over TCP). The contract
//! under test is uniform — every bad line yields a *structured* error
//! in the caller's dialect, and the connection survives to serve the
//! next request. Nothing here panics, hangs, or closes early.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use ckptfp::api::{wire, ErrorCode, Executor, ExecutorConfig, JobRequest, JobResponse};
use ckptfp::coordinator::{serve, ServiceConfig, ServiceHandle};

// ---------------------------------------------------------------------------
// Decoder corpus
// ---------------------------------------------------------------------------

fn decode_err(line: &str) -> ckptfp::api::ApiError {
    wire::decode_request(line).expect_err("hostile line must not decode")
}

#[test]
fn oversized_line_is_rejected_with_the_limit_named() {
    let line = format!(
        "{{\"v\": 2, \"op\": \"ping\", \"pad\": \"{}\"}}",
        "x".repeat(wire::MAX_LINE_BYTES)
    );
    let err = decode_err(&line);
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("exceeds"), "{}", err.message);
    assert!(
        err.message.contains(&wire::MAX_LINE_BYTES.to_string()),
        "the limit must be named: {}",
        err.message
    );
}

#[test]
fn truncated_json_is_invalid_json() {
    for line in ["{\"v\": 2, \"op\":", "{\"v\": 2, \"op\": \"ping\"", "{", "[1, 2", "\"unterminated"] {
        let err = decode_err(line);
        assert_eq!(err.code, ErrorCode::InvalidJson, "{line}");
    }
}

#[test]
fn deep_nesting_is_rejected_not_overflowed() {
    // 10k open brackets: a recursion bomb the parser's depth guard
    // must catch long before the stack does.
    let line = format!("{{\"v\": 2, \"op\": \"plan\", \"scenario\": {}", "[".repeat(10_000));
    let err = decode_err(&line);
    assert_eq!(err.code, ErrorCode::InvalidJson);
    assert!(err.message.contains("nesting"), "{}", err.message);
}

#[test]
fn wrong_typed_fields_are_structured_errors() {
    // A number where the op string belongs.
    let err = decode_err("{\"v\": 2, \"op\": 42}");
    assert_eq!(err.code, ErrorCode::UnknownOp, "{}", err.message);

    // An array where the scenario object belongs.
    let err = decode_err("{\"v\": 2, \"op\": \"plan\", \"scenario\": [1, 2]}");
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("scenario"), "{}", err.message);

    // A scalar at the top level is not a request object at all.
    let err = decode_err("42");
    assert_eq!(err.code, ErrorCode::BadRequest);

    // A future protocol version is refused, not half-parsed.
    let err = decode_err("{\"v\": 3, \"op\": \"ping\"}");
    assert_eq!(err.code, ErrorCode::UnsupportedVersion);
}

#[test]
fn hostile_service_envelopes_are_structured_errors() {
    // The tenant must be a string...
    let err = decode_err("{\"v\": 2, \"op\": \"ping\", \"tenant\": 7}");
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("tenant"), "{}", err.message);

    // ...and a non-empty one of at most 64 bytes.
    let err = decode_err("{\"v\": 2, \"op\": \"ping\", \"tenant\": \"\"}");
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("1 to 64"), "{}", err.message);
    let long = format!("{{\"v\": 2, \"op\": \"ping\", \"tenant\": \"{}\"}}", "t".repeat(65));
    let err = decode_err(&long);
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("1 to 64"), "{}", err.message);

    // The streaming opt-in must be a boolean.
    let err = decode_err("{\"v\": 2, \"op\": \"ping\", \"stream\": \"yes\"}");
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("stream"), "{}", err.message);

    // An exactly-64-byte tenant is the boundary case that must pass.
    let edge = format!("{{\"v\": 2, \"op\": \"ping\", \"tenant\": \"{}\"}}", "t".repeat(64));
    let (d, meta) = wire::decode_request_meta(&edge).unwrap();
    assert!(matches!(d.request, JobRequest::Ping));
    assert_eq!(meta.tenant.as_deref().map(str::len), Some(64));
}

#[test]
fn hostile_stream_frames_are_structured_errors() {
    fn frame_err(line: &str) -> ckptfp::api::ApiError {
        wire::decode_stream_event(line).expect_err("hostile frame must not decode")
    }

    // A frame marker that is neither "partial" nor "final".
    let err = frame_err("{\"v\": 2, \"ok\": true, \"frame\": \"middle\", \"seq\": 0}");
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("partial"), "{}", err.message);
    let err = frame_err("{\"v\": 2, \"ok\": true, \"frame\": 7}");
    assert_eq!(err.code, ErrorCode::BadRequest);

    // Partial frames missing each mandatory field in turn.
    let err = frame_err("{\"v\": 2, \"ok\": true, \"frame\": \"partial\", \"seq\": 0, \"item\": {}}");
    assert!(err.message.contains("job"), "{}", err.message);
    let err =
        frame_err("{\"v\": 2, \"ok\": true, \"frame\": \"partial\", \"job\": \"sweep\", \"item\": {}}");
    assert!(err.message.contains("seq"), "{}", err.message);
    let err =
        frame_err("{\"v\": 2, \"ok\": true, \"frame\": \"partial\", \"job\": \"sweep\", \"seq\": 0}");
    assert!(err.message.contains("item"), "{}", err.message);

    // A final frame whose payload is not a response at all.
    let err = frame_err("{\"frame\": \"final\", \"seq\": 1}");
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("ok"), "{}", err.message);

    // Garbage bytes fail as JSON before frame dispatch.
    let err = frame_err("{\"frame\": ");
    assert_eq!(err.code, ErrorCode::InvalidJson);
}

// ---------------------------------------------------------------------------
// Live-service corpus: the connection survives every bad line
// ---------------------------------------------------------------------------

struct RawConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl RawConn {
    fn connect(addr: &str) -> RawConn {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        RawConn { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    /// Send raw bytes (a trailing newline is appended) and read one
    /// response line.
    fn roundtrip_bytes(&mut self, payload: &[u8]) -> String {
        self.writer.write_all(payload).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut out = String::new();
        self.reader.read_line(&mut out).unwrap();
        assert!(!out.is_empty(), "server closed the connection");
        out.trim_end_matches('\n').to_string()
    }

    fn expect_pong(&mut self) {
        let line = self.roundtrip_bytes(wire::encode_request(&JobRequest::Ping).as_bytes());
        match wire::decode_response(&line).unwrap() {
            JobResponse::Pong => {}
            other => panic!("expected pong, got {other:?}"),
        }
    }
}

fn start_service() -> (ServiceHandle, String) {
    let handle = serve(
        Executor::new(ExecutorConfig::default()),
        ServiceConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let addr = handle.addr.to_string();
    (handle, addr)
}

#[test]
fn connection_survives_the_whole_hostile_corpus() {
    let (handle, addr) = start_service();
    let mut conn = RawConn::connect(&addr);

    // Invalid UTF-8: never reaches the decoder, still answered.
    let line = conn.roundtrip_bytes(b"\xff\xfe{\"op\": \"ping\"}");
    match wire::decode_response(&line).unwrap() {
        JobResponse::Error(e) => {
            assert_eq!(e.code, ErrorCode::InvalidJson);
            assert!(e.message.contains("UTF-8"), "{}", e.message);
        }
        other => panic!("expected an error for invalid UTF-8, got {other:?}"),
    }
    conn.expect_pong();

    // Truncated JSON over the wire.
    let line = conn.roundtrip_bytes(b"{\"v\": 2, \"op\":");
    match wire::decode_response(&line).unwrap() {
        JobResponse::Error(e) => assert_eq!(e.code, ErrorCode::InvalidJson),
        other => panic!("expected an error for truncated JSON, got {other:?}"),
    }
    conn.expect_pong();

    // Oversized line: past the wire limit but below the hard cutoff
    // where the service gives up on the connection entirely.
    let big = vec![b'x'; wire::MAX_LINE_BYTES + 10];
    let line = conn.roundtrip_bytes(&big);
    match wire::decode_response(&line).unwrap() {
        JobResponse::Error(e) => {
            assert_eq!(e.code, ErrorCode::BadRequest);
            assert!(e.message.contains("exceeds"), "{}", e.message);
        }
        other => panic!("expected an error for the oversized line, got {other:?}"),
    }
    conn.expect_pong();

    // Wrong-typed op, this time in the legacy dialect: the error comes
    // back in the legacy shape (no "v" marker).
    let line = conn.roundtrip_bytes(b"{\"op\": 42}");
    assert!(line.contains("\"ok\":false"), "{line}");
    assert!(!line.contains("\"v\":"), "legacy dialect must not carry 'v': {line}");
    conn.expect_pong();

    // The error tally reflects the corpus.
    let line = conn.roundtrip_bytes(wire::encode_request(&JobRequest::Stats).as_bytes());
    match wire::decode_response(&line).unwrap() {
        JobResponse::Stats(s) => assert!(s.errors >= 4, "stats: {s:?}"),
        other => panic!("expected stats, got {other:?}"),
    }

    drop(conn);
    handle.stop();
}

#[test]
fn hostile_envelopes_over_the_wire_keep_the_connection_alive() {
    let (handle, addr) = start_service();
    let mut conn = RawConn::connect(&addr);

    // A bad tenant is a structured v2 error, not a dropped connection.
    let line = conn.roundtrip_bytes(b"{\"v\": 2, \"op\": \"ping\", \"tenant\": []}");
    match wire::decode_response(&line).unwrap() {
        JobResponse::Error(e) => {
            assert_eq!(e.code, ErrorCode::BadRequest);
            assert!(e.message.contains("tenant"), "{}", e.message);
        }
        other => panic!("expected an error for the bad tenant, got {other:?}"),
    }

    // A well-formed tenant-tagged request on the same connection works.
    let tagged = wire::encode_request_tagged(
        &JobRequest::Ping,
        &wire::RequestMeta { tenant: Some("acme".into()), stream: false },
    );
    let line = conn.roundtrip_bytes(tagged.as_bytes());
    match wire::decode_response(&line).unwrap() {
        JobResponse::Pong => {}
        other => panic!("expected pong, got {other:?}"),
    }

    // Asking to stream a non-streamable job degrades to a single
    // ordinary line — pinned here as the client-visible behavior.
    let tagged = wire::encode_request_tagged(
        &JobRequest::Ping,
        &wire::RequestMeta { tenant: None, stream: true },
    );
    let line = conn.roundtrip_bytes(tagged.as_bytes());
    match wire::decode_stream_event(&line).unwrap() {
        wire::StreamEvent::Final { seq: None, response: JobResponse::Pong } => {}
        other => panic!("expected an unframed pong, got {other:?}"),
    }

    drop(conn);
    handle.stop();
}
