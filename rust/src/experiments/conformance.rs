//! The `conformance` experiment: the §5.1 "analysis corroborated by
//! simulation" claim as a catalog entry — runs the quick conformance
//! grid through the [`crate::verify`] subsystem and renders the
//! verdicts as a table (the same data `ckptfp verify` writes to
//! `CONFORMANCE.json`).

use super::{ExpOptions, ExperimentResult};
use crate::report::Table;
use crate::verify::{run_conformance, GridKind, VerifyOptions};

/// Map the experiment harness's replication knob onto the comparator:
/// `opts.reps` is the base batch, the escalation budget is 8×.
pub fn conformance(opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    let reps0 = opts.reps.max(8);
    let vopts = VerifyOptions { reps0, budget: reps0 * 8, workers: opts.workers, ..Default::default() };
    let report = run_conformance(GridKind::Quick, None, &vopts)?;

    let mut t = Table::new([
        "case", "policy", "domain", "analytic", "band lo", "band hi", "sim", "ci95", "reps",
        "verdict",
    ]);
    for c in &report.cases {
        t.row([
            c.name.clone(),
            c.policy.clone(),
            if c.domain.is_first_order() { "first-order".into() } else { "out-of-domain".into() },
            format!("{:.4}", c.analytic),
            format!("{:.4}", c.band.0),
            format!("{:.4}", c.band.1),
            format!("{:.4}", c.sim_mean),
            format!("{:.4}", c.sim_ci95),
            c.reps.to_string(),
            c.verdict.to_string(),
        ]);
    }
    let mut result = ExperimentResult::default();
    result.tables.push((
        format!(
            "conformance-{} ({} pass / {} fail / {} inconclusive)",
            report.grid, report.n_pass, report.n_fail, report.n_inconclusive
        ),
        t,
    ));
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conformance_experiment_renders_every_case() {
        // Tiny budget: this is a smoke test of the wiring, not of the
        // verdicts (test_verify.rs covers those on a real budget).
        let opts = ExpOptions { reps: 2, ..ExpOptions::quick() };
        let r = conformance(&opts).unwrap();
        assert_eq!(r.tables.len(), 1);
        let rendered = r.render();
        let n_cases = crate::verify::conformance_grid(GridKind::Quick).len();
        for needle in ["exp-n16-none-Young", "verdict", "out-of-domain", "first-order"] {
            assert!(rendered.contains(needle), "missing '{needle}':\n{rendered}");
        }
        // One row per case plus header material.
        assert!(rendered.matches('\n').count() >= n_cases);
    }
}
