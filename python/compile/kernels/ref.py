"""Pure-jnp oracle for the ``waste_grid`` Pallas kernel.

Straight-line vectorized re-statement of Eqs. (1), (3), (4), (5), (6) of
the paper, written independently of the kernel's tiling so that a test
failure localizes to the kernel, not to the math.
"""

from __future__ import annotations

import jax.numpy as jnp

from .waste_grid import COLS, NSTRAT  # single source of truth for layout


def waste_grid_ref(params, u):
    """f32[B, NPARAM], f32[G] -> f32[B, NSTRAT, G]."""
    col = lambda name: params[:, COLS[name]][:, None]

    c, dr = col("C"), col("DR")
    inv_mu, r, p = col("inv_mu"), col("r"), col("p")
    ef, m = col("Ef"), col("M")
    inv_mup, inv_munp = col("inv_muP"), col("inv_muNP")
    frac_reg, i1, tp = col("frac_reg"), col("I1"), col("TP")
    tmax, r_over_p = col("Tmax"), col("r_over_p")

    t = c + u[None, :] * (tmax - c)

    # Eq. (1), q = 0 (Young / Daly baseline).
    s0 = c / t + inv_mu * (t / 2.0 + dr)
    # Eq. (1), q = 1 (exact-date predictions, always trusted).
    s1 = c / t + inv_mu * ((1.0 - r) * t / 2.0 + dr + (r / p) * c)
    # Eq. (5): Instant — window treated as an exact prediction at t0.
    s2 = (
        c / t
        + inv_mu
        * ((1.0 - r) * t / 2.0 + dr + (r / p) * c + r * jnp.minimum(ef, t / 2.0))
    )
    # Eq. (6), q = 1: NoCkptI.
    s3 = (
        (frac_reg / t + inv_mup) * c
        + p * inv_mup * ef
        + frac_reg * inv_munp * t / 2.0
        + (p * inv_mup + frac_reg * inv_munp) * dr
    )
    # Eq. (4), q = 1: WithCkptI with proactive period T_P.
    s4 = (
        (frac_reg / t + i1 * inv_mup / tp + inv_mup) * c
        + p * inv_mup * tp
        + frac_reg * inv_munp * t / 2.0
        + (p * inv_mup + frac_reg * inv_munp) * dr
    )
    # Eq. (3), q = 1: prediction + preventive migration.
    s5 = c / t + inv_mu * ((1.0 - r) * (t / 2.0 + dr) + (r / p) * m)

    out = jnp.stack([s0, s1, s2, s3, s4, s5], axis=1)
    assert out.shape[1] == NSTRAT
    return out
