//! CSV emission for archived experiment results.

use std::io::Write;
use std::path::Path;

use super::FigureData;

/// Write rows of stringly data with a header.
pub fn write_csv(
    path: &Path,
    header: &[&str],
    rows: &[Vec<String>],
) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        anyhow::ensure!(row.len() == header.len(), "csv row width mismatch");
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Long-format figure dump: figure,series,x,y.
pub fn write_figure_csv(path: &Path, fig: &FigureData) -> anyhow::Result<()> {
    let mut rows = Vec::new();
    for s in &fig.series {
        for (x, y) in &s.points {
            rows.push(vec![fig.name.clone(), s.label.clone(), x.to_string(), y.to_string()]);
        }
    }
    write_csv(path, &["figure", "series", "x", "y"], &rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::Series;

    #[test]
    fn round_trips_to_disk() {
        let dir = std::env::temp_dir().join(format!("ckptfp-csv-{}", std::process::id()));
        let path = dir.join("test.csv");
        let mut fig = FigureData::new("figX", "N", "waste");
        let s = fig.series_mut("Young");
        s.push(1.0, 0.5);
        s.push(2.0, 0.25);
        write_figure_csv(&path, &fig).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("figure,series,x,y\n"));
        assert!(text.contains("figX,Young,1,0.5"));
        std::fs::remove_dir_all(&dir).unwrap();
        let _ = Series::new("unused");
    }

    #[test]
    fn rejects_ragged() {
        let dir = std::env::temp_dir().join(format!("ckptfp-csv2-{}", std::process::id()));
        let path = dir.join("bad.csv");
        let err = write_csv(&path, &["a", "b"], &[vec!["1".into()]]);
        assert!(err.is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
