//! Paper-reproduction bench harness (`cargo bench --bench paper`).
//!
//! One target per table AND figure of §5:
//!   fig4 fig5 fig6 fig7   waste vs N (both predictors × false-pred law)
//!   fig8 fig9 fig10 fig11 recall/precision sweeps
//!   tab1 tab2             execution-time tables (Weibull 0.7 / 0.5)
//!   tab3                  predictor catalog
//!
//! ```bash
//! cargo bench --bench paper                  # everything, quick reps
//! cargo bench --bench paper -- fig4          # one experiment
//! cargo bench --bench paper -- tab1 --reps 100 --best-period
//! ```
//!
//! Output: the paper-format series/tables on stdout plus CSV dumps in
//! results/. Absolute numbers come from this simulator, not the
//! authors' testbed; EXPERIMENTS.md records the shape comparison.

use ckptfp::cli::Args;
use ckptfp::experiments::{all_experiments, run_experiment, ExpOptions};

fn main() {
    // `cargo bench -- <args>` also passes "--bench"; drop harness noise.
    let raw: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| a != "--bench" && !a.starts_with("--save-baseline"))
        .collect();
    let mut args = match Args::parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    let mut opts = ExpOptions::quick();
    opts.reps = args.get("reps", 16).unwrap_or(16);
    opts.workers = args.get("workers", opts.workers).unwrap_or(opts.workers);
    opts.best_period = args.switch("best-period");
    opts.bp_reps = args.get("bp-reps", opts.bp_reps).unwrap_or(opts.bp_reps);
    opts.bp_candidates = args.get("bp-candidates", opts.bp_candidates).unwrap_or(opts.bp_candidates);
    let out_dir = args.get_str("out", "results");

    let ids: Vec<String> = {
        let mut ids: Vec<String> = args
            .positional()
            .iter()
            .cloned()
            .chain(args.command().map(String::from))
            .collect();
        if ids.is_empty() || ids == ["all"] {
            ids = all_experiments().into_iter().map(String::from).collect();
        }
        ids
    };

    let mut failures = 0;
    for id in &ids {
        println!("==================================================================");
        println!("== {id} (reps = {}, best_period = {})", opts.reps, opts.best_period);
        println!("==================================================================");
        let t0 = std::time::Instant::now();
        match run_experiment(id, &opts) {
            Ok(result) => {
                print!("{}", result.render());
                if let Err(e) = result.write_csvs(std::path::Path::new(&out_dir)) {
                    eprintln!("[{id}] csv write failed: {e:#}");
                }
                println!("[{id}] completed in {:.1}s", t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                eprintln!("[{id}] FAILED: {e:#}");
                failures += 1;
            }
        }
    }
    if failures > 0 {
        std::process::exit(1);
    }
}
