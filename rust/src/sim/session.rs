//! Reusable simulation sessions: build once per (scenario, strategy),
//! replicate many times.
//!
//! [`crate::sim::simulate_once`] pays the full setup bill on every
//! replication: distribution specs re-parsed from strings, a fresh
//! trace generator, a fresh engine with fresh event buffers. A
//! [`SimSession`] does all of that exactly once — distributions are
//! parsed and validated at construction, the engine and generator are
//! built once, and [`SimSession::run`] replays replication `rep` by
//! *resetting* them (RNG substreams re-derived from `(seed, rep)`,
//! buffers cleared in place). Steady state is allocation- and
//! parse-free, and the outcomes are bit-identical to the one-shot path
//! (`session_matches_oneshot` below pins this).

use std::sync::Arc;
use std::time::Instant;

use super::platform::{store, PlatformSource, PlatformSpec};
use super::{Engine, Outcome, Policy, SimConfig};
use crate::config::Scenario;
use crate::rng::trust_seed;
use crate::strategies::StrategySpec;
use crate::trace::{bank, ReplaySource, TraceBank, TraceGen};

/// A (scenario, policy) pair prepared for repeated replication.
///
/// Two backings share one public surface: the classic *live* engine
/// over a [`TraceGen`], and a *replay* engine over a shared
/// [`TraceBank`] ([`SimSession::replay`]) that serves pre-materialized
/// event streams and falls back to a lazily-built live engine for any
/// replication the bank cannot soundly serve (underrun past the
/// horizon, un-materialized rep). Either way, `run(rep)` is
/// bit-identical to `simulate_once(scenario, spec, rep)`.
pub struct SimSession {
    seed: u64,
    inner: Backing,
}

enum Backing {
    Live(Engine<TraceGen>),
    /// Multi-node platform engine ([`SimSession::on_platform`]). Live
    /// only — platforms decline trace-bank replay.
    Platform(Engine<PlatformSource>),
    Replay {
        engine: Engine<ReplaySource>,
        /// Live fallback engine, built on first use.
        fallback: Option<Box<Engine<TraceGen>>>,
        scenario: Box<Scenario>,
        policy: Policy,
        lead: f64,
    },
}

impl SimSession {
    /// Parse, validate and pre-build everything `run` needs. This is
    /// the only place a session touches spec strings or the allocator
    /// (beyond buffer growth inside the first replications).
    pub fn new(scenario: &Scenario, spec: &StrategySpec) -> anyhow::Result<SimSession> {
        Self::with_lead(scenario, spec, spec.required_lead(scenario.platform.c))
    }

    /// Like [`SimSession::new`] but with an explicit predictor lead for
    /// the trace generator (the `abl-lead` study drives leads below the
    /// strategy's own requirement).
    pub fn with_lead(scenario: &Scenario, spec: &StrategySpec, lead: f64) -> anyhow::Result<SimSession> {
        Self::from_policy_with_lead(scenario, Policy::from_spec(spec, scenario.platform.c), lead)
    }

    /// Session for an arbitrary [`Policy`] — the non-paper strategies'
    /// entry point. For a [`Policy::Paper`] built from the same spec
    /// this is bit-identical to [`SimSession::new`].
    pub fn from_policy(scenario: &Scenario, policy: Policy) -> anyhow::Result<SimSession> {
        Self::from_policy_with_lead(scenario, policy, policy.required_lead(scenario.platform.c))
    }

    /// [`SimSession::from_policy`] with an explicit predictor lead.
    pub fn from_policy_with_lead(
        scenario: &Scenario,
        policy: Policy,
        lead: f64,
    ) -> anyhow::Result<SimSession> {
        let cfg = SimConfig::from_scenario(scenario);
        cfg.validate()?;
        let source = TraceGen::new(scenario, lead, scenario.seed, 0)?;
        // The trust seed is per-replication; `run` resets it before use.
        let engine = Engine::with_policy(&cfg, policy, source, 0);
        Ok(SimSession { seed: scenario.seed, inner: Backing::Live(engine) })
    }

    /// Replay-backed session over a shared [`TraceBank`]: replications
    /// are served from the bank's arena instead of re-sampling the
    /// trace, bit-identical to the live path (underruns past the
    /// bank's horizon fall back to a live engine automatically).
    ///
    /// The bank must have been built for this scenario's seed and for
    /// exactly the lead this policy requires — a mismatch would replay
    /// a *different* experiment and is rejected here.
    pub fn replay(
        bank: Arc<TraceBank>,
        scenario: &Scenario,
        policy: Policy,
    ) -> anyhow::Result<SimSession> {
        let cfg = SimConfig::from_scenario(scenario);
        cfg.validate()?;
        let lead = policy.sanitized(cfg.c).required_lead(cfg.c);
        anyhow::ensure!(
            bank.seed() == scenario.seed,
            "trace bank was built for seed {} but the scenario uses seed {}",
            bank.seed(),
            scenario.seed
        );
        anyhow::ensure!(
            bank.lead() == lead,
            "trace bank was built with lead {} but the policy requires lead {}",
            bank.lead(),
            lead
        );
        let engine = Engine::with_policy(&cfg, policy, ReplaySource::new(bank), 0);
        Ok(SimSession {
            seed: scenario.seed,
            inner: Backing::Replay {
                engine,
                fallback: None,
                scenario: Box::new(scenario.clone()),
                policy,
                lead,
            },
        })
    }

    /// Platform-backed session: the engine consumes a
    /// [`PlatformSource`] (K merged per-node streams, optional
    /// correlation) and the store's coordination costs replace the
    /// scenario's raw C/R. At `spec == PlatformSpec::default()` this is
    /// bit-identical to [`SimSession::from_policy`] on every outcome
    /// field (pinned in `tests/test_platform.rs`).
    pub fn on_platform(
        scenario: &Scenario,
        policy: Policy,
        pspec: &PlatformSpec,
    ) -> anyhow::Result<SimSession> {
        pspec.validate()?;
        let mut cfg = SimConfig::from_scenario(scenario);
        let (c_eff, r_eff) = store::effective_costs(pspec, cfg.c, cfg.r);
        cfg.c = c_eff;
        cfg.r = r_eff;
        cfg.validate()?;
        // Lead against the *effective* commit cost: proactive actions
        // must fit the coordinated checkpoint they trigger. At the
        // default spec this is the raw C — the from_policy path.
        let lead = policy.required_lead(cfg.c);
        let source = PlatformSource::new(scenario, pspec, lead, scenario.seed, 0)?;
        let engine = Engine::with_policy(&cfg, policy, source, 0);
        Ok(SimSession { seed: scenario.seed, inner: Backing::Platform(engine) })
    }

    /// [`SimSession::on_platform`] from a strategy spec — the policy is
    /// built against the platform's effective commit cost, mirroring
    /// [`SimSession::new`]'s use of the scenario's C.
    pub fn new_on_platform(
        scenario: &Scenario,
        spec: &StrategySpec,
        pspec: &PlatformSpec,
    ) -> anyhow::Result<SimSession> {
        let (c_eff, _) = store::effective_costs(pspec, scenario.platform.c, scenario.platform.r);
        Self::on_platform(scenario, Policy::from_spec(spec, c_eff), pspec)
    }

    /// Whether this session serves replications from a trace bank.
    pub fn is_replay(&self) -> bool {
        matches!(self.inner, Backing::Replay { .. })
    }

    /// Whether this session runs a multi-node platform engine.
    pub fn is_platform(&self) -> bool {
        matches!(self.inner, Backing::Platform(_))
    }

    /// Execute replication `rep`. Reuses the session's engine and
    /// generator via reset — same trace and trust streams as
    /// `simulate_once(scenario, spec, rep)`, bit for bit, whichever
    /// backing serves it.
    pub fn run(&mut self, rep: u64) -> Outcome {
        let started = Instant::now();
        let mut out = match &mut self.inner {
            Backing::Live(engine) => {
                engine.source_mut().reset(self.seed, rep);
                engine.reset(trust_seed(self.seed, rep));
                engine.run_to_completion()
            }
            Backing::Platform(engine) => {
                engine.source_mut().reset(self.seed, rep);
                engine.reset(trust_seed(self.seed, rep));
                engine.run_to_completion()
            }
            Backing::Replay { engine, fallback, scenario, policy, lead } => {
                let covered = engine.source_mut().reset(rep);
                let replayed = covered.then(|| {
                    engine.reset(trust_seed(self.seed, rep));
                    engine.run_to_completion()
                });
                match replayed {
                    // The replayed run stayed inside the bank's horizon:
                    // its outcome is the live outcome, to the bit.
                    Some(out) if !engine.source_mut().underrun() => {
                        bank::note_replay_served();
                        out
                    }
                    // Underrun or un-materialized rep: the replayed
                    // outcome (if any) may have diverged past the
                    // horizon — discard it and re-run live.
                    _ => {
                        bank::note_fallback_taken();
                        let live = match fallback {
                            Some(live) => live,
                            None => {
                                let cfg = SimConfig::from_scenario(scenario);
                                let source =
                                    TraceGen::new(scenario, *lead, self.seed, rep)
                                        .expect("scenario validated at session build");
                                fallback
                                    .insert(Box::new(Engine::with_policy(&cfg, *policy, source, 0)))
                            }
                        };
                        live.source_mut().reset(self.seed, rep);
                        live.reset(trust_seed(self.seed, rep));
                        live.run_to_completion()
                    }
                }
            }
        };
        out.sim_seconds = started.elapsed().as_secs_f64();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;
    use crate::model::{Capping, StrategyKind};
    use crate::sim::simulate_once;
    use crate::strategies::spec_for;

    fn scenario(window: f64) -> Scenario {
        let pred = if window > 0.0 {
            Predictor::windowed(0.85, 0.82, window)
        } else {
            Predictor::exact(0.85, 0.82)
        };
        let mut s = Scenario::paper(1 << 16, pred);
        s.fault_dist = crate::dist::DistSpec::weibull(0.7);
        s.work = 2.0e5;
        s
    }

    #[test]
    fn session_matches_oneshot() {
        // The determinism contract: buffer reuse must not perturb a
        // single bit of the outcome relative to fresh construction.
        for (kind, window) in [
            (StrategyKind::Young, 0.0),
            (StrategyKind::ExactPrediction, 0.0),
            (StrategyKind::NoCkptI, 300.0),
            (StrategyKind::WithCkptI, 3000.0),
            (StrategyKind::Migration, 0.0),
        ] {
            let s0 = scenario(window);
            let s = crate::experiments::scenario_for(kind, &s0);
            let spec = spec_for(kind, &s, Capping::Uncapped);
            let mut session = SimSession::new(&s, &spec).unwrap();
            // Deliberately out of order so reuse cannot hide behind a
            // sequential-rep coincidence.
            for rep in [2u64, 0, 5, 2, 9] {
                let a = session.run(rep);
                let b = simulate_once(&s, &spec, rep).unwrap();
                assert_eq!(a.makespan, b.makespan, "{} rep {rep}", spec.name);
                assert_eq!(a.n_faults, b.n_faults, "{} rep {rep}", spec.name);
                assert_eq!(a.n_preds, b.n_preds, "{} rep {rep}", spec.name);
                assert_eq!(a.n_ckpts, b.n_ckpts, "{} rep {rep}", spec.name);
                assert_eq!(a.n_segments, b.n_segments, "{} rep {rep}", spec.name);
                assert_eq!(a.lost_work, b.lost_work, "{} rep {rep}", spec.name);
            }
        }
    }

    #[test]
    fn rerunning_a_rep_is_idempotent() {
        let s = scenario(0.0);
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let mut session = SimSession::new(&s, &spec).unwrap();
        let a = session.run(4);
        let b = session.run(4);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.n_segments, b.n_segments);
    }

    #[test]
    fn replay_session_matches_live_session_bit_for_bit() {
        // The bank bit-identity contract at the session level, including
        // a fractional trust probability so the pre-sampled uniforms are
        // genuinely consulted.
        let s0 = scenario(3000.0);
        let s = crate::experiments::scenario_for(StrategyKind::WithCkptI, &s0);
        let mut spec = spec_for(StrategyKind::WithCkptI, &s, Capping::Uncapped);
        spec.q = 0.6; // fractional: every prediction draws a trust uniform
        let policy = Policy::from_spec(&spec, s.platform.c);
        let lead = policy.required_lead(s.platform.c);
        let bank = Arc::new(TraceBank::try_build(&s, lead, 6).unwrap().expect("bank fits"));
        let mut replay = SimSession::replay(bank, &s, policy).unwrap();
        let mut live = SimSession::from_policy(&s, policy).unwrap();
        assert!(replay.is_replay() && !live.is_replay());
        for rep in [0u64, 3, 1, 3, 5] {
            let a = replay.run(rep);
            let b = live.run(rep);
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "rep {rep}");
            assert_eq!(a.n_segments, b.n_segments, "rep {rep}");
            assert_eq!(a.n_trusted, b.n_trusted, "rep {rep}");
            assert_eq!(a.n_preds, b.n_preds, "rep {rep}");
            assert_eq!(a.lost_work.to_bits(), b.lost_work.to_bits(), "rep {rep}");
        }
    }

    #[test]
    fn replay_falls_back_for_unmaterialized_reps() {
        let s = scenario(0.0);
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let policy = Policy::from_spec(&spec, s.platform.c);
        let lead = policy.required_lead(s.platform.c);
        let bank = Arc::new(TraceBank::try_build(&s, lead, 2).unwrap().unwrap());
        let mut replay = SimSession::replay(bank, &s, policy).unwrap();
        // Rep 7 is not in the bank: served by the live fallback, still
        // bit-identical to the one-shot path.
        let a = replay.run(7);
        let b = simulate_once(&s, &spec, 7).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.n_segments, b.n_segments);
    }

    #[test]
    fn replay_rejects_mismatched_banks() {
        let s = scenario(0.0);
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let policy = Policy::from_spec(&spec, s.platform.c);
        let lead = policy.required_lead(s.platform.c);
        let bank = Arc::new(TraceBank::try_build(&s, lead, 1).unwrap().unwrap());
        // Seed mismatch.
        let mut other = s.clone();
        other.seed += 1;
        assert!(SimSession::replay(bank.clone(), &other, policy).is_err());
        // Lead mismatch (migration policies need M > C here).
        let mig = Policy::Paper {
            t_r: spec.t_r,
            q: 1.0,
            proactive: crate::strategies::ProactiveMode::Migrate { m: lead * 2.0 },
        };
        assert!(SimSession::replay(bank, &s, mig).is_err());
    }

    #[test]
    fn single_platform_session_matches_the_classic_engine() {
        // The 1-node special case is the classic session, bit for bit.
        let s0 = scenario(300.0);
        let s = crate::experiments::scenario_for(StrategyKind::NoCkptI, &s0);
        let spec = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
        let policy = Policy::from_spec(&spec, s.platform.c);
        let pspec = PlatformSpec::default();
        let mut platform = SimSession::on_platform(&s, policy, &pspec).unwrap();
        let mut classic = SimSession::from_policy(&s, policy).unwrap();
        assert!(platform.is_platform() && !classic.is_platform());
        for rep in [0u64, 3, 1] {
            let a = platform.run(rep);
            let b = classic.run(rep);
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "rep {rep}");
            assert_eq!(a.n_segments, b.n_segments, "rep {rep}");
            assert_eq!(a.n_preds, b.n_preds, "rep {rep}");
            assert_eq!(a.lost_work.to_bits(), b.lost_work.to_bits(), "rep {rep}");
        }
    }

    #[test]
    fn platform_session_rejects_zero_nodes() {
        let s = scenario(0.0);
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let pspec = PlatformSpec { nodes: 0, ..PlatformSpec::default() };
        let err = SimSession::new_on_platform(&s, &spec, &pspec).unwrap_err().to_string();
        assert!(err.contains("at least one node"), "{err}");
    }

    #[test]
    fn invalid_scenario_fails_at_construction() {
        let mut s = scenario(0.0);
        s.fault_dist = crate::dist::DistSpec::weibull(-2.0);
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let err = SimSession::new(&s, &spec).unwrap_err().to_string();
        assert!(err.contains("weibull:-2"), "error should name the spec: {err}");
    }
}
