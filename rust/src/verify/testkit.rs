//! Mini property-testing harness (substrate: no `proptest` offline).
//! Part of the [`crate::verify`] subsystem; re-exported at the crate
//! root as `ckptfp::testkit` for the existing property suites.
//!
//! Deterministic: every case derives from a fixed seed, so failures
//! reproduce. On failure the harness reports the case index and the
//! generated inputs via the panic message of the property itself.

use crate::rng::Pcg64;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xbead }
    }
}

/// A generator of random values for property tests.
pub struct Gen<'a> {
    rng: &'a mut Pcg64,
}

impl<'a> Gen<'a> {
    /// Uniform f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Log-uniform f64 in [lo, hi) — natural for periods/MTBFs.
    pub fn log_f64(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.f64(lo.ln(), hi.ln())).exp()
    }

    /// Uniform integer in [lo, hi].
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Pick one element.
    pub fn choose<'b, T>(&mut self, items: &'b [T]) -> &'b T {
        &items[self.rng.below(items.len() as u64) as usize]
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bernoulli(p)
    }
}

/// Run `property` on `cfg.cases` generated cases. The property panics
/// to signal failure; the harness decorates the panic with the case
/// number so the seed can be replayed.
pub fn check<F: FnMut(&mut Gen<'_>)>(cfg: Config, mut property: F) {
    for case in 0..cfg.cases {
        let mut rng = crate::rng::substream(cfg.seed, "testkit", case as u64);
        let mut gen = Gen { rng: &mut rng };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut gen);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {case} (seed {:#x}): {msg}", cfg.seed);
        }
    }
}

/// Shorthand with default config.
pub fn check_default<F: FnMut(&mut Gen<'_>)>(property: F) {
    check(Config::default(), property);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check_default(|g| {
            let x = g.f64(0.0, 10.0);
            assert!((0.0..10.0).contains(&x));
        });
    }

    #[test]
    fn reports_failing_case() {
        let result = std::panic::catch_unwind(|| {
            check(Config { cases: 32, seed: 1 }, |g| {
                let x = g.u64(0, 100);
                assert!(x < 95, "x was {x}");
            });
        });
        let msg = match result {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("property failed on case"), "{msg}");
    }

    #[test]
    fn log_uniform_in_range() {
        check_default(|g| {
            let x = g.log_f64(10.0, 1000.0);
            assert!((10.0..1000.0).contains(&x));
        });
    }

    #[test]
    fn choose_covers_all() {
        let items = [1, 2, 3];
        let mut seen = [false; 3];
        check(Config { cases: 200, seed: 3 }, |g| {
            seen[*g.choose(&items) as usize - 1] = true;
        });
        assert_eq!(seen, [true, true, true]);
    }
}
