//! Lockstep batch replay: advance a block of replications together
//! over one shared [`TraceBank`] arena.
//!
//! The scalar replay path ([`SimSession::replay`]) walks one
//! replication at a time: reset the replay cursor, run the engine to
//! completion, fall back to a live engine on underrun. A
//! [`BatchEngine`] keeps `lanes` replay engines over the *same*
//! `Arc<TraceBank>` and advances a chunk of replications in three
//! struct-of-arrays phases — reset every lane's cursor, run every
//! covered lane to completion, then collect outcomes in chunk order
//! with a per-lane fallback to a shared lazily-built live engine on
//! bank underrun, exactly the rule the scalar replay arm applies.
//!
//! Replications are independent by construction (every per-rep stream
//! is re-derived from `(seed, rep)`), so the lane interleaving is
//! unobservable: a lockstep chunk produces the same outcomes, pushed
//! into the same accumulators in the same order, as the scalar loop —
//! bit for bit. That identity is the contract (pinned in
//! `tests/test_batch.rs`); the win is locality: the chunk's replay
//! cursors walk one contiguous arena front-to-back instead of
//! ping-ponging a single engine across the whole bank.
//!
//! [`BatchRunner`] is the knob surface: `Lockstep` wraps a
//! [`BatchEngine`], `Scalar` wraps a plain [`SimSession`], and the
//! grid folds ([`fold_waste_grid`], [`fold_waste_grid_retaining`]) and
//! the range runner ([`run_replication_range_batched`]) consume either
//! through one interface, so callers pick the backing with
//! [`BatchOptions`] and nothing downstream changes shape.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::runner::ReplicationAgg;
use super::{Engine, Outcome, Policy, SimConfig, SimSession};
use crate::config::Scenario;
use crate::coordinator::{run_parallel_fold, try_run_parallel_fold};
use crate::rng::trust_seed;
use crate::trace::{bank, ReplaySource, TraceBank, TraceGen};
use crate::util::stats::Summary;

/// How many replications a lockstep chunk advances together when no
/// caller overrides it. Wide enough to amortize the chunk bookkeeping,
/// small enough that a chunk's replay cursors stay within a few arena
/// pages of each other.
pub const DEFAULT_LANES: usize = 8;

/// Lane-count knob for the batch engine. `lanes = 0` selects the
/// pinned scalar path (one [`SimSession`] per worker, exactly the
/// pre-batch code shape); any other value runs chunks of that width
/// over the trace bank — through the wide SoA kernel
/// ([`crate::sim::wide::WideKernel`]) when `wide` is set (the
/// default), through per-lane lockstep engines otherwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchOptions {
    /// Replications advanced per chunk; `0` = scalar path.
    pub lanes: usize,
    /// Use the wide SoA kernel for eligible (bank-backed single-node
    /// replay) surfaces; `false` keeps the per-lane lockstep engines.
    /// Irrelevant when `lanes == 0`.
    pub wide: bool,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions { lanes: DEFAULT_LANES, wide: true }
    }
}

impl BatchOptions {
    /// The pinned scalar path: no batch chunks anywhere.
    pub fn scalar() -> BatchOptions {
        BatchOptions { lanes: 0, wide: false }
    }

    /// Lockstep chunks without the wide SoA kernel (the PR 8 shape).
    pub fn lockstep(lanes: usize) -> BatchOptions {
        BatchOptions { lanes, wide: false }
    }

    /// Whether this configuration disables the batch engines.
    pub fn is_scalar(&self) -> bool {
        self.lanes == 0
    }
}

// Crate-wide batch counters, surfaced on the service `stats` op next
// to the bank counters (same pattern as `trace::bank`).
static LANES_RUN: AtomicU64 = AtomicU64::new(0);
static LANE_FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the lockstep counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchCounters {
    /// Replications that went through a lockstep chunk (served or
    /// fallen back — every lane a [`BatchEngine`] advanced).
    pub lanes_run: u64,
    /// Lanes that hit bank underrun (or an un-materialized rep) inside
    /// a chunk and were re-run on the live fallback engine.
    pub lane_fallbacks: u64,
}

/// Read the crate-wide lockstep counters.
pub fn counters() -> BatchCounters {
    BatchCounters {
        lanes_run: LANES_RUN.load(Ordering::Relaxed),
        lane_fallbacks: LANE_FALLBACKS.load(Ordering::Relaxed),
    }
}

/// The lockstep engine: `width` replay engines over one shared bank,
/// advanced a chunk of replications at a time.
///
/// Construction mirrors [`SimSession::replay`]'s validation — the bank
/// must match the scenario's seed and the policy's required lead — and
/// the per-lane fallback mirrors its underrun rule, so every
/// replication's outcome is bit-identical to the scalar replay path.
pub struct BatchEngine {
    seed: u64,
    width: usize,
    lanes: Vec<Engine<ReplaySource>>,
    /// SoA phase state: which lanes the bank covers this chunk.
    covered: Vec<bool>,
    /// SoA phase state: per-lane replayed outcomes, pending collection.
    replayed: Vec<Option<Outcome>>,
    /// Live fallback engine, built on first underrun, shared by all
    /// lanes (the fallback runs one lane at a time, in chunk order).
    fallback: Option<Box<Engine<TraceGen>>>,
    scenario: Box<Scenario>,
    policy: Policy,
    lead: f64,
}

impl BatchEngine {
    /// Build a lockstep engine of `lanes.max(1)` lanes over `bank`.
    /// Rejects bank/scenario seed mismatches and bank/policy lead
    /// mismatches, exactly like [`SimSession::replay`].
    pub fn new(
        bank: Arc<TraceBank>,
        scenario: &Scenario,
        policy: Policy,
        lanes: usize,
    ) -> anyhow::Result<BatchEngine> {
        let cfg = SimConfig::from_scenario(scenario);
        cfg.validate()?;
        let lead = policy.sanitized(cfg.c).required_lead(cfg.c);
        anyhow::ensure!(
            bank.seed() == scenario.seed,
            "trace bank was built for seed {} but the scenario uses seed {}",
            bank.seed(),
            scenario.seed
        );
        anyhow::ensure!(
            bank.lead() == lead,
            "trace bank was built with lead {} but the policy requires lead {}",
            bank.lead(),
            lead
        );
        let width = lanes.max(1);
        let lanes = (0..width)
            .map(|_| Engine::with_policy(&cfg, policy, ReplaySource::new(bank.clone()), 0))
            .collect();
        Ok(BatchEngine {
            seed: scenario.seed,
            width,
            lanes,
            covered: Vec::with_capacity(width),
            replayed: Vec::with_capacity(width),
            fallback: None,
            scenario: Box::new(scenario.clone()),
            policy,
            lead,
        })
    }

    /// Chunk width (the `lanes` this engine was built with).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Advance one chunk of at most `width` replications in lockstep
    /// and hand each `(rep, outcome)` to `sink` in chunk order.
    ///
    /// Three phases over the lane block:
    /// 1. point every lane's replay cursor at its replication,
    /// 2. run every covered lane to completion,
    /// 3. collect in chunk order, re-running any lane whose replay
    ///    underran the bank on the shared live fallback engine —
    ///    the same per-rep rule as the scalar replay session.
    fn run_chunk<F: FnMut(u64, &Outcome)>(&mut self, reps: &[u64], sink: &mut F) {
        debug_assert!(reps.len() <= self.width, "chunk wider than the engine");
        // Phase 1: reset replay cursors; note which reps the bank holds.
        self.covered.clear();
        for (lane, &rep) in reps.iter().enumerate() {
            self.covered.push(self.lanes[lane].source_mut().reset(rep));
        }
        // Phase 2: advance covered lanes to completion.
        self.replayed.clear();
        for (lane, &rep) in reps.iter().enumerate() {
            let out = self.covered[lane].then(|| {
                let started = Instant::now();
                let engine = &mut self.lanes[lane];
                engine.reset(trust_seed(self.seed, rep));
                let mut out = engine.run_to_completion();
                out.sim_seconds = started.elapsed().as_secs_f64();
                out
            });
            self.replayed.push(out);
        }
        // Phase 3: collect in chunk order; underrun lanes re-run live.
        for (lane, &rep) in reps.iter().enumerate() {
            match self.replayed[lane].take() {
                // The lane stayed inside the bank's horizon: its
                // outcome is the live outcome, to the bit.
                Some(out) if !self.lanes[lane].source_mut().underrun() => {
                    bank::note_replay_served();
                    sink(rep, &out);
                }
                // Underrun or un-materialized rep: the replayed
                // outcome (if any) may have diverged past the horizon
                // — discard it and re-run live.
                _ => {
                    LANE_FALLBACKS.fetch_add(1, Ordering::Relaxed);
                    bank::note_fallback_taken();
                    let started = Instant::now();
                    let fallback = &mut self.fallback;
                    let live = match fallback {
                        Some(live) => live,
                        None => {
                            let cfg = SimConfig::from_scenario(&self.scenario);
                            let source =
                                TraceGen::new(&self.scenario, self.lead, self.seed, rep)
                                    .expect("scenario validated at batch build");
                            fallback
                                .insert(Box::new(Engine::with_policy(&cfg, self.policy, source, 0)))
                        }
                    };
                    live.source_mut().reset(self.seed, rep);
                    live.reset(trust_seed(self.seed, rep));
                    let mut out = live.run_to_completion();
                    out.sim_seconds = started.elapsed().as_secs_f64();
                    sink(rep, &out);
                }
            }
        }
        LANES_RUN.fetch_add(reps.len() as u64, Ordering::Relaxed);
    }
}

/// One replication backend for the grid folds and the range runner:
/// either a lockstep [`BatchEngine`] or the pinned scalar
/// [`SimSession`] path. Both deliver `(rep, outcome)` pairs in the
/// order the replications were requested, so swapping one for the
/// other cannot change a downstream accumulator by a bit.
pub enum BatchRunner {
    /// Wide SoA chunks over a trace bank (columnar lane state).
    Wide(crate::sim::wide::WideKernel),
    /// Lockstep chunks over a trace bank (per-lane scalar engines).
    Lockstep(BatchEngine),
    /// One scalar session — replay-backed or live, the caller decides.
    Scalar(SimSession),
}

impl BatchRunner {
    /// Run an arbitrary replication list (the range runner's strided
    /// per-worker schedule), delivering outcomes in list order.
    pub fn run_reps<F: FnMut(u64, &Outcome)>(&mut self, reps: &[u64], mut sink: F) {
        match self {
            BatchRunner::Scalar(session) => {
                for &rep in reps {
                    let out = session.run(rep);
                    sink(rep, &out);
                }
            }
            BatchRunner::Lockstep(engine) => {
                for chunk in reps.chunks(engine.width()) {
                    engine.run_chunk(chunk, &mut sink);
                }
            }
            BatchRunner::Wide(kernel) => {
                for chunk in reps.chunks(kernel.width()) {
                    kernel.run_chunk(chunk, &mut sink);
                }
            }
        }
    }

    /// Run the contiguous block `[rep_lo, rep_hi)` in ascending rep
    /// order — the grid folds' unit of work.
    pub fn run_block<F: FnMut(u64, &Outcome)>(&mut self, rep_lo: u64, rep_hi: u64, mut sink: F) {
        match self {
            BatchRunner::Scalar(session) => {
                for rep in rep_lo..rep_hi {
                    let out = session.run(rep);
                    sink(rep, &out);
                }
            }
            BatchRunner::Lockstep(engine) => {
                let width = engine.width() as u64;
                let mut chunk = Vec::with_capacity(engine.width());
                let mut lo = rep_lo;
                while lo < rep_hi {
                    let hi = (lo + width).min(rep_hi);
                    chunk.clear();
                    chunk.extend(lo..hi);
                    engine.run_chunk(&chunk, &mut sink);
                    lo = hi;
                }
            }
            BatchRunner::Wide(kernel) => {
                let width = kernel.width() as u64;
                let mut chunk = Vec::with_capacity(kernel.width());
                let mut lo = rep_lo;
                while lo < rep_hi {
                    let hi = (lo + width).min(rep_hi);
                    chunk.clear();
                    chunk.extend(lo..hi);
                    kernel.run_chunk(&chunk, &mut sink);
                    lo = hi;
                }
            }
        }
    }
}

/// Batch-runner counterpart of
/// [`crate::sim::runner::fold_waste_product`]: fold point-major
/// `(point, rep_lo, rep_hi)` blocks through the pool with one cached
/// runner per worker per point. Per-point waste summaries are pushed
/// in ascending rep order within each block and merged in worker
/// order — the same push and merge sequence as the scalar fold, so a
/// `Scalar` factory reproduces it bit for bit and a `Lockstep` factory
/// is pinned to match.
pub fn fold_waste_grid<F>(
    tasks: &[(usize, u64, u64)],
    n_points: usize,
    workers: usize,
    make: F,
) -> Vec<Summary>
where
    F: Fn(usize) -> BatchRunner + Sync,
{
    run_parallel_fold(
        tasks,
        workers,
        || (vec![Summary::new(); n_points], None::<(usize, BatchRunner)>),
        |(mut sums, mut cache), &(pi, rep_lo, rep_hi)| {
            let stale = cache.as_ref().map(|(cached, _)| *cached != pi).unwrap_or(true);
            if stale {
                cache = Some((pi, make(pi)));
            }
            let (_, runner) = cache.as_mut().expect("cache filled above");
            runner.run_block(rep_lo, rep_hi, |_, out| sums[pi].push(out.waste()));
            (sums, cache)
        },
        |(a, _), (b, _)| (a.iter().zip(&b).map(|(x, y)| x.merge(y)).collect(), None),
    )
    .0
}

/// Batch-runner counterpart of
/// [`crate::sim::runner::fold_waste_product_retaining`]: the same fold
/// as [`fold_waste_grid`] plus a point-major per-replication waste
/// matrix (`matrix[pi * span + (rep - rep_lo)]`) for the CRN
/// paired-difference prune. Each slot is written exactly once, so the
/// matrix is deterministic regardless of worker scheduling.
pub fn fold_waste_grid_retaining<F>(
    tasks: &[(usize, u64, u64)],
    n_points: usize,
    rep_lo: u64,
    rep_hi: u64,
    workers: usize,
    make: F,
) -> (Vec<Summary>, Vec<f64>)
where
    F: Fn(usize) -> BatchRunner + Sync,
{
    let span = (rep_hi - rep_lo) as usize;
    let (sums, cells, _) = run_parallel_fold(
        tasks,
        workers,
        || {
            (
                vec![Summary::new(); n_points],
                Vec::<(usize, f64)>::new(),
                None::<(usize, BatchRunner)>,
            )
        },
        |(mut sums, mut cells, mut cache), &(pi, lo, hi)| {
            let stale = cache.as_ref().map(|(cached, _)| *cached != pi).unwrap_or(true);
            if stale {
                cache = Some((pi, make(pi)));
            }
            let (_, runner) = cache.as_mut().expect("cache filled above");
            runner.run_block(lo, hi, |rep, out| {
                let w = out.waste();
                sums[pi].push(w);
                cells.push((pi * span + (rep - rep_lo) as usize, w));
            });
            (sums, cells, cache)
        },
        |(a, mut ca, _), (b, cb, _)| {
            ca.extend(cb);
            (a.iter().zip(&b).map(|(x, y)| x.merge(y)).collect(), ca, None)
        },
    );
    let mut matrix = vec![f64::NAN; n_points * span];
    for (slot, w) in cells {
        matrix[slot] = w;
    }
    (sums, matrix)
}

/// Batch-runner counterpart of
/// [`crate::sim::run_replication_range_with`]: aggregate replications
/// `[rep_lo, rep_hi)` across the pool through [`BatchRunner`]s.
///
/// The scalar range runner folds the rep list with a deterministic
/// stride — worker `w` runs reps `w, w + W, …` in order and partials
/// merge in worker order. This runner reproduces that schedule
/// exactly: it folds over *worker indices*, each worker materializing
/// its own strided rep list and pushing outcomes in stride order, so
/// for a fixed worker count the aggregate matches the scalar runner
/// bit for bit (counters exactly, summaries to the bit) whatever the
/// lane width.
pub fn run_replication_range_batched<M>(
    rep_lo: u64,
    rep_hi: u64,
    workers: usize,
    make: M,
) -> anyhow::Result<ReplicationAgg>
where
    M: Fn() -> anyhow::Result<BatchRunner> + Sync,
{
    // Surface configuration errors here, once, instead of panicking in
    // a worker.
    drop(make()?);
    let n_reps = rep_hi.saturating_sub(rep_lo);
    if n_reps == 0 {
        return Ok(ReplicationAgg::default());
    }
    // Same clamp as the scalar fold (workers capped at the item count),
    // so the per-worker stride — and with it the merge order — agrees.
    let w_eff = workers.max(1).min(n_reps.min(usize::MAX as u64) as usize);
    let worker_ids: Vec<usize> = (0..w_eff).collect();
    let agg = try_run_parallel_fold(
        &worker_ids,
        w_eff,
        ReplicationAgg::default,
        |mut agg, &w| {
            let mut runner = make().expect("runner validated above");
            let reps: Vec<u64> = (rep_lo + w as u64..rep_hi).step_by(w_eff).collect();
            runner.run_reps(&reps, |_, out| agg.push(out));
            agg
        },
        |a, b| a.merge(b),
    )
    .map_err(anyhow::Error::new)?;
    Ok(agg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Predictor, Scenario};
    use crate::model::{Capping, StrategyKind};
    use crate::sim::run_replication_range_with;
    use crate::strategies::spec_for;

    fn scenario() -> Scenario {
        let mut s = Scenario::paper(1 << 16, Predictor::exact(0.85, 0.82));
        s.fault_dist = crate::dist::DistSpec::Exp;
        s.work = 2.0e5;
        s
    }

    /// Everything except wall-clock `sim_seconds` must agree exactly.
    fn assert_agg_bit_identical(a: &ReplicationAgg, b: &ReplicationAgg) {
        assert_eq!(a.n_reps, b.n_reps);
        assert_eq!(a.n_completed, b.n_completed);
        assert_eq!(a.n_faults, b.n_faults);
        assert_eq!(a.n_faults_unpredicted, b.n_faults_unpredicted);
        assert_eq!(a.n_preds, b.n_preds);
        assert_eq!(a.n_true_preds, b.n_true_preds);
        assert_eq!(a.n_trusted, b.n_trusted);
        assert_eq!(a.n_ckpts, b.n_ckpts);
        assert_eq!(a.n_proactive_ckpts, b.n_proactive_ckpts);
        assert_eq!(a.n_migrations, b.n_migrations);
        assert_eq!(a.n_faults_avoided, b.n_faults_avoided);
        assert_eq!(a.n_segments, b.n_segments);
        assert_eq!(a.lost_work.to_bits(), b.lost_work.to_bits());
        assert_eq!(a.waste.mean().to_bits(), b.waste.mean().to_bits());
        assert_eq!(a.waste.ci95().to_bits(), b.waste.ci95().to_bits());
        assert_eq!(a.makespan.mean().to_bits(), b.makespan.mean().to_bits());
    }

    #[test]
    fn lockstep_chunks_match_the_scalar_replay_loop() {
        let s0 = scenario();
        let s = crate::experiments::scenario_for(StrategyKind::ExactPrediction, &s0);
        let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
        let policy = Policy::from_spec(&spec, s.platform.c);
        let lead = policy.required_lead(s.platform.c);
        let bank = Arc::new(TraceBank::try_build(&s, lead, 10).unwrap().expect("bank fits"));
        let mut scalar = ReplicationAgg::default();
        let mut session = SimSession::replay(bank.clone(), &s, policy).unwrap();
        for rep in 0..10 {
            scalar.push(&session.run(rep));
        }
        for lanes in [1usize, 3, 8] {
            let mut agg = ReplicationAgg::default();
            let mut runner =
                BatchRunner::Lockstep(BatchEngine::new(bank.clone(), &s, policy, lanes).unwrap());
            runner.run_block(0, 10, |_, out| agg.push(out));
            assert_agg_bit_identical(&agg, &scalar);
        }
    }

    #[test]
    fn batched_range_matches_the_scalar_range_runner() {
        let s = scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let policy = Policy::from_spec(&spec, s.platform.c);
        let lead = policy.required_lead(s.platform.c);
        let bank = Arc::new(TraceBank::try_build(&s, lead, 12).unwrap().expect("bank fits"));
        for workers in [1usize, 3] {
            let scalar = run_replication_range_with(0, 12, workers, || {
                SimSession::replay(bank.clone(), &s, policy)
            })
            .unwrap();
            let batched = run_replication_range_batched(0, 12, workers, || {
                BatchEngine::new(bank.clone(), &s, policy, 4).map(BatchRunner::Lockstep)
            })
            .unwrap();
            assert_agg_bit_identical(&batched, &scalar);
        }
    }

    #[test]
    fn underrun_lanes_fall_back_mid_chunk() {
        // A bank holding only reps 0..3 forces the back half of every
        // chunk onto the live fallback — outcomes must still match the
        // scalar replay session (which falls back the same way).
        let s = scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let policy = Policy::from_spec(&spec, s.platform.c);
        let lead = policy.required_lead(s.platform.c);
        let bank = Arc::new(TraceBank::try_build(&s, lead, 3).unwrap().expect("bank fits"));
        let before = counters();
        let mut scalar = ReplicationAgg::default();
        let mut session = SimSession::replay(bank.clone(), &s, policy).unwrap();
        for rep in 0..8 {
            scalar.push(&session.run(rep));
        }
        let mut agg = ReplicationAgg::default();
        let mut runner =
            BatchRunner::Lockstep(BatchEngine::new(bank, &s, policy, 4).unwrap());
        runner.run_block(0, 8, |_, out| agg.push(out));
        assert_agg_bit_identical(&agg, &scalar);
        let after = counters();
        assert!(after.lanes_run >= before.lanes_run + 8);
        assert!(after.lane_fallbacks >= before.lane_fallbacks + 5, "reps 3..8 fell back");
    }

    #[test]
    fn scalar_runner_is_the_session_verbatim() {
        let s = scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let mut direct = SimSession::new(&s, &spec).unwrap();
        let mut via_runner = BatchRunner::Scalar(SimSession::new(&s, &spec).unwrap());
        let mut got = Vec::new();
        via_runner.run_reps(&[2, 0, 5], |rep, out| got.push((rep, out.makespan)));
        assert_eq!(got.len(), 3);
        for (rep, makespan) in got {
            assert_eq!(makespan.to_bits(), direct.run(rep).makespan.to_bits(), "rep {rep}");
        }
    }

    #[test]
    fn batch_engine_rejects_mismatched_banks() {
        let s = scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let policy = Policy::from_spec(&spec, s.platform.c);
        let lead = policy.required_lead(s.platform.c);
        let bank = Arc::new(TraceBank::try_build(&s, lead, 1).unwrap().unwrap());
        let mut other = s.clone();
        other.seed += 1;
        assert!(BatchEngine::new(bank, &other, policy, 4).is_err());
    }

    #[test]
    fn options_default_and_scalar_knob() {
        assert_eq!(BatchOptions::default().lanes, DEFAULT_LANES);
        assert!(!BatchOptions::default().is_scalar());
        assert!(BatchOptions::default().wide, "wide kernel is the default where eligible");
        assert!(BatchOptions::scalar().is_scalar());
        assert!(!BatchOptions::scalar().wide);
        assert_eq!(BatchOptions::lockstep(4), BatchOptions { lanes: 4, wide: false });
    }

    #[test]
    fn fold_waste_grid_matches_the_scalar_product_fold() {
        use crate::sim::runner::{fold_waste_product, rep_blocks};
        let s = scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let policy = Policy::from_spec(&spec, s.platform.c);
        let lead = policy.required_lead(s.platform.c);
        let bank = Arc::new(TraceBank::try_build(&s, lead, 6).unwrap().expect("bank fits"));
        let points: Vec<usize> = (0..3).collect();
        let tasks = rep_blocks(&points, 0, 6, 2);
        let scalar = fold_waste_product(&tasks, 3, 2, |_| {
            SimSession::replay(bank.clone(), &s, policy).unwrap()
        });
        let batched = fold_waste_grid(&tasks, 3, 2, |_| {
            BatchRunner::Lockstep(BatchEngine::new(bank.clone(), &s, policy, 4).unwrap())
        });
        for (a, b) in scalar.iter().zip(&batched) {
            assert_eq!(a.mean().to_bits(), b.mean().to_bits());
            assert_eq!(a.ci95().to_bits(), b.ci95().to_bits());
        }
        let (sums, matrix) = fold_waste_grid_retaining(&tasks, 3, 0, 6, 2, |_| {
            BatchRunner::Lockstep(BatchEngine::new(bank.clone(), &s, policy, 4).unwrap())
        });
        assert!(matrix.iter().all(|w| w.is_finite()));
        for (a, b) in scalar.iter().zip(&sums) {
            assert_eq!(a.mean().to_bits(), b.mean().to_bits());
        }
    }
}
