//! Reusable simulation sessions: build once per (scenario, strategy),
//! replicate many times.
//!
//! [`crate::sim::simulate_once`] pays the full setup bill on every
//! replication: distribution specs re-parsed from strings, a fresh
//! trace generator, a fresh engine with fresh event buffers. A
//! [`SimSession`] does all of that exactly once — distributions are
//! parsed and validated at construction, the engine and generator are
//! built once, and [`SimSession::run`] replays replication `rep` by
//! *resetting* them (RNG substreams re-derived from `(seed, rep)`,
//! buffers cleared in place). Steady state is allocation- and
//! parse-free, and the outcomes are bit-identical to the one-shot path
//! (`session_matches_oneshot` below pins this).

use std::time::Instant;

use super::{Engine, Outcome, Policy, SimConfig};
use crate::config::Scenario;
use crate::strategies::StrategySpec;
use crate::trace::TraceGen;

/// A (scenario, policy) pair prepared for repeated replication.
pub struct SimSession {
    seed: u64,
    engine: Engine<TraceGen>,
}

impl SimSession {
    /// Parse, validate and pre-build everything `run` needs. This is
    /// the only place a session touches spec strings or the allocator
    /// (beyond buffer growth inside the first replications).
    pub fn new(scenario: &Scenario, spec: &StrategySpec) -> anyhow::Result<SimSession> {
        Self::with_lead(scenario, spec, spec.required_lead(scenario.platform.c))
    }

    /// Like [`SimSession::new`] but with an explicit predictor lead for
    /// the trace generator (the `abl-lead` study drives leads below the
    /// strategy's own requirement).
    pub fn with_lead(scenario: &Scenario, spec: &StrategySpec, lead: f64) -> anyhow::Result<SimSession> {
        Self::from_policy_with_lead(scenario, Policy::from_spec(spec, scenario.platform.c), lead)
    }

    /// Session for an arbitrary [`Policy`] — the non-paper strategies'
    /// entry point. For a [`Policy::Paper`] built from the same spec
    /// this is bit-identical to [`SimSession::new`].
    pub fn from_policy(scenario: &Scenario, policy: Policy) -> anyhow::Result<SimSession> {
        Self::from_policy_with_lead(scenario, policy, policy.required_lead(scenario.platform.c))
    }

    /// [`SimSession::from_policy`] with an explicit predictor lead.
    pub fn from_policy_with_lead(
        scenario: &Scenario,
        policy: Policy,
        lead: f64,
    ) -> anyhow::Result<SimSession> {
        let cfg = SimConfig::from_scenario(scenario);
        cfg.validate()?;
        let source = TraceGen::new(scenario, lead, scenario.seed, 0)?;
        // The trust seed is per-replication; `run` resets it before use.
        let engine = Engine::with_policy(&cfg, policy, source, 0);
        Ok(SimSession { seed: scenario.seed, engine })
    }

    /// Execute replication `rep`. Reuses the session's engine and
    /// generator via reset — same trace and trust streams as
    /// `simulate_once(scenario, spec, rep)`, bit for bit.
    pub fn run(&mut self, rep: u64) -> Outcome {
        self.engine.source_mut().reset(self.seed, rep);
        self.engine.reset(self.seed ^ (rep << 17) ^ 0xA5);
        let started = Instant::now();
        let mut out = self.engine.run_to_completion();
        out.sim_seconds = started.elapsed().as_secs_f64();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;
    use crate::model::{Capping, StrategyKind};
    use crate::sim::simulate_once;
    use crate::strategies::spec_for;

    fn scenario(window: f64) -> Scenario {
        let pred = if window > 0.0 {
            Predictor::windowed(0.85, 0.82, window)
        } else {
            Predictor::exact(0.85, 0.82)
        };
        let mut s = Scenario::paper(1 << 16, pred);
        s.fault_dist = crate::dist::DistSpec::weibull(0.7);
        s.work = 2.0e5;
        s
    }

    #[test]
    fn session_matches_oneshot() {
        // The determinism contract: buffer reuse must not perturb a
        // single bit of the outcome relative to fresh construction.
        for (kind, window) in [
            (StrategyKind::Young, 0.0),
            (StrategyKind::ExactPrediction, 0.0),
            (StrategyKind::NoCkptI, 300.0),
            (StrategyKind::WithCkptI, 3000.0),
            (StrategyKind::Migration, 0.0),
        ] {
            let s0 = scenario(window);
            let s = crate::experiments::scenario_for(kind, &s0);
            let spec = spec_for(kind, &s, Capping::Uncapped);
            let mut session = SimSession::new(&s, &spec).unwrap();
            // Deliberately out of order so reuse cannot hide behind a
            // sequential-rep coincidence.
            for rep in [2u64, 0, 5, 2, 9] {
                let a = session.run(rep);
                let b = simulate_once(&s, &spec, rep).unwrap();
                assert_eq!(a.makespan, b.makespan, "{} rep {rep}", spec.name);
                assert_eq!(a.n_faults, b.n_faults, "{} rep {rep}", spec.name);
                assert_eq!(a.n_preds, b.n_preds, "{} rep {rep}", spec.name);
                assert_eq!(a.n_ckpts, b.n_ckpts, "{} rep {rep}", spec.name);
                assert_eq!(a.n_segments, b.n_segments, "{} rep {rep}", spec.name);
                assert_eq!(a.lost_work, b.lost_work, "{} rep {rep}", spec.name);
            }
        }
    }

    #[test]
    fn rerunning_a_rep_is_idempotent() {
        let s = scenario(0.0);
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let mut session = SimSession::new(&s, &spec).unwrap();
        let a = session.run(4);
        let b = session.run(4);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.n_segments, b.n_segments);
    }

    #[test]
    fn invalid_scenario_fails_at_construction() {
        let mut s = scenario(0.0);
        s.fault_dist = crate::dist::DistSpec::weibull(-2.0);
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let err = SimSession::new(&s, &spec).unwrap_err().to_string();
        assert!(err.contains("weibull:-2"), "error should name the spec: {err}");
    }
}
