"""L1 Pallas kernel: tiled evaluation of the six waste surfaces.

Evaluates, for every configuration ``b`` and period-grid point ``j``,
the closed-form expected waste of the six checkpointing strategies of
Aupy, Robert, Vivien & Zaidouni (2012):

    s=0  Young           (q=0, Eq. 1)     s=3  NoCkptI   (q=1, Eq. 6)
    s=1  ExactPrediction (q=1, Eq. 1)     s=4  WithCkptI (q=1, Eq. 4)
    s=2  Instant         (q=1, Eq. 5)     s=5  Migration (q=1, Eq. 3)

The kernel consumes a *pre-expanded* parameter matrix (see
``model.expand_params``) so that it stays pure column algebra — no
control flow, no transcendental calls; the only non-linear ops are
div / min.  The period grid is materialized inside the kernel from a
normalized coordinate vector ``u`` in [0, 1]:

    T(b, j) = C_b + u_j * (Tmax_b - C_b)

so the caller (Rust L3) is free to choose the grid *spacing* (uniform,
quadratic, ...) at run time without recompiling the artifact.

TPU mapping: the grid dimension G sits on the 128-wide lane axis, the
batch dimension on sublanes; one (BM=8, GN=128) tile keeps all six
surfaces resident in VMEM (8*6*128*4 B = 24 KiB).  There is no
contraction so the MXU is not used; the kernel is VPU/store-bound.
``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime runs as-is.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Expanded-parameter column layout (shared with model.expand_params and ref.py).
NPARAM = 16
COLS = {
    "C": 0,          # checkpoint duration
    "DR": 1,         # D + R (downtime + recovery)
    "inv_mu": 2,     # 1 / mu        (platform MTBF)
    "r": 3,          # predictor recall
    "p": 4,          # predictor precision
    "I": 5,          # prediction-window length
    "Ef": 6,         # E_I^(f): mean in-window fault offset (I/2 for uniform)
    "M": 7,          # migration duration (s=5)
    "inv_muP": 8,    # 1 / mu_P  = r / (p mu)
    "inv_muNP": 9,   # 1 / mu_NP = (1 - r) / mu
    "frac_reg": 10,  # 1 - I'/mu_P (q=1), clamped to [0, 1]
    "I1": 11,        # I' at q=1: (1-p) I + p Ef
    "TP": 12,        # T_P^opt (Eq. 7, snapped so that I / T_P is integral)
    "Tmax": 13,      # upper end of the period grid (alpha * mu)
    "r_over_p": 14,  # r / p
    "pad": 15,
}

NSTRAT = 6
DEFAULT_BM = 8    # batch-tile (sublane) size
DEFAULT_GN = 128  # grid-tile (lane) size


def _surfaces_tile(params, u):
    """Column algebra for one (bm, gn) tile.

    params: f32[bm, NPARAM]; u: f32[gn] -> f32[bm, NSTRAT, gn].
    Shared subexpressions (1/T, T/2, the s3/s4 common tail) are computed
    once — this is the whole perf story of the kernel.
    """
    col = lambda name: params[:, COLS[name]][:, None]  # (bm, 1)

    c = col("C")
    dr = col("DR")
    inv_mu = col("inv_mu")
    r = col("r")
    p = col("p")
    ef = col("Ef")
    m = col("M")
    inv_mup = col("inv_muP")
    inv_munp = col("inv_muNP")
    frac_reg = col("frac_reg")
    i1 = col("I1")
    tp = col("TP")
    tmax = col("Tmax")
    r_over_p = col("r_over_p")

    t = c + u[None, :] * (tmax - c)          # (bm, gn) period grid
    inv_t = 1.0 / t
    half = 0.5 * t

    c_over_t = c * inv_t
    # s0: Young (q=0).  Eq. (1) with q=0.
    s0 = c_over_t + inv_mu * (half + dr)
    # s1: ExactPrediction (q=1).  Eq. (1) with q=1.
    s1 = c_over_t + inv_mu * ((1.0 - r) * half + dr + r_over_p * c)
    # s2: Instant (q=1).  Eq. (5): s1 plus the in-window loss term.
    s2 = s1 + inv_mu * r * jnp.minimum(ef, half)
    # s3/s4 share the regular-mode unpredicted-fault + (D+R) tail.
    reg_np = frac_reg * inv_munp
    tail = reg_np * half + (p * inv_mup + reg_np) * dr
    # s3: NoCkptI (q=1).  Eq. (6).
    s3 = (frac_reg * inv_t + inv_mup) * c + p * inv_mup * ef + tail
    # s4: WithCkptI (q=1).  Eq. (4) with T_P precomputed per Eq. (7).
    s4 = (
        (frac_reg * inv_t + i1 * inv_mup / tp + inv_mup) * c
        + p * inv_mup * tp
        + tail
    )
    # s5: Migration (q=1).  Eq. (3).
    s5 = c_over_t + inv_mu * ((1.0 - r) * (half + dr) + r_over_p * m)

    return jnp.stack([s0, s1, s2, s3, s4, s5], axis=1)


def _kernel(params_ref, u_ref, out_ref):
    out_ref[...] = _surfaces_tile(params_ref[...], u_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "gn"))
def waste_grid(params, u, *, bm: int = DEFAULT_BM, gn: int = DEFAULT_GN):
    """Evaluate all six waste surfaces on the period grid.

    Args:
      params: f32[B, NPARAM] expanded parameters (``model.expand_params``).
      u:      f32[G] normalized grid coordinates in [0, 1].
      bm, gn: tile sizes; B % bm == 0 and G % gn == 0.

    Returns:
      f32[B, NSTRAT, G] unmasked waste surfaces (domain capping is L2's job).
    """
    b, npar = params.shape
    (g,) = u.shape
    if npar != NPARAM:
        raise ValueError(f"params must have {NPARAM} columns, got {npar}")
    bm = min(bm, b)
    gn = min(gn, g)
    if b % bm or g % gn:
        raise ValueError(f"B={b} G={g} not divisible by tile ({bm}, {gn})")

    return pl.pallas_call(
        _kernel,
        grid=(b // bm, g // gn),
        in_specs=[
            pl.BlockSpec((bm, NPARAM), lambda i, j: (i, 0)),
            pl.BlockSpec((gn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, NSTRAT, gn), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, NSTRAT, g), params.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(params, u)
