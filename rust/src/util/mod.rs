//! Shared utilities: statistics, logging, JSON, time units, cancellation.

pub mod cancel;
pub mod json;
pub mod logging;
pub mod stats;
pub mod units;

/// Relative closeness for floating-point comparisons in tests and
/// validation paths.
pub fn approx_eq(a: f64, b: f64, rel: f64) -> bool {
    let scale = a.abs().max(b.abs()).max(1e-12);
    (a - b).abs() <= rel * scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_eq_basic() {
        assert!(approx_eq(1.0, 1.0 + 1e-9, 1e-6));
        assert!(!approx_eq(1.0, 1.1, 1e-6));
        assert!(approx_eq(0.0, 0.0, 1e-12));
    }
}
