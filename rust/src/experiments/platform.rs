//! Platform scaling — the multi-node subsystem's headline experiment:
//! simulated waste as the same aggregate failure rate is spread over
//! K nodes of the `sim::platform` layer.
//!
//! Setting: Exponential faults at the paper's N = 2^16 aggregate MTBF,
//! the Yu predictor (p = 0.82, r = 0.85, I = 300 s). Three series per
//! node count:
//!
//! * `Young` / `ExactPrediction` on an *uncorrelated* K-node platform —
//!   by Poisson superposition these should be flat in K (the aggregate
//!   law is invariant), which is exactly the conformance subsystem's
//!   N-node acceptance criterion re-plotted as an experiment;
//! * `Young@correlated` on a spatially-correlated platform with a
//!   cascade kernel — the waste excess over the flat series is the
//!   measured cost of correlated failures the closed forms cannot see.

use super::{replicate_stat_with, scenario_for, ExpOptions, ExperimentResult};
use crate::config::{predictor_yu, Scenario};
use crate::model::{Capping, StrategyKind};
use crate::report::{FigureData, Table};
use crate::sim::{Outcome, PlatformSpec, SimSession};
use crate::strategies::spec_for;

/// Node counts swept by the experiment.
pub fn node_counts() -> Vec<u64> {
    vec![1, 2, 4, 8, 16]
}

/// The correlated variant at `nodes`: groups of 4, a 25% spatial
/// sympathy and a 10% cascade boost over a 5-minute window.
pub fn correlated_spec(nodes: u64) -> PlatformSpec {
    PlatformSpec {
        nodes,
        group: nodes.min(4),
        spatial: 0.25,
        cascade: 0.1,
        ..PlatformSpec::default()
    }
}

/// The base scenario: §5 platform at N = 2^16 under Exponential faults
/// (so the uncorrelated series has a closed-form reference).
fn base_scenario() -> Scenario {
    let mut s = Scenario::paper(1 << 16, predictor_yu(300.0));
    s.fault_dist = crate::dist::DistSpec::Exp;
    s
}

/// Waste of Young and EXACTPREDICTION over the node-count sweep, on
/// uncorrelated and correlated platforms, plus a summary table.
pub fn platform_scaling(opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    let mut fig = FigureData::new("platform-scaling", "nodes", "waste");
    let mut t = Table::new(["nodes", "platform", "strategy", "waste", "ci95"]);
    let base = base_scenario();

    let mut run = |label: &str, kind: StrategyKind, pspec: &PlatformSpec| {
        let s = scenario_for(kind, &base);
        let spec = spec_for(kind, &s, Capping::Uncapped);
        let sum = replicate_stat_with(
            opts.reps,
            opts.workers,
            || {
                SimSession::new_on_platform(&s, &spec, pspec)
                    .expect("platform specs built by this experiment are valid")
            },
            Outcome::waste,
        );
        fig.series_mut(label).push(pspec.nodes as f64, sum.mean());
        t.row([
            pspec.nodes.to_string(),
            pspec.to_string(),
            kind.name().to_string(),
            format!("{:.4}", sum.mean()),
            format!("{:.4}", sum.ci95()),
        ]);
    };

    for k in node_counts() {
        let flat = PlatformSpec { nodes: k, ..PlatformSpec::default() };
        run("Young", StrategyKind::Young, &flat);
        run("ExactPrediction", StrategyKind::ExactPrediction, &flat);
        run("Young@correlated", StrategyKind::Young, &correlated_spec(k));
    }

    let mut result = ExperimentResult::default();
    result.figures.push(fig);
    result.tables.push(("platform-scaling".into(), t));
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn platform_scaling_structure() {
        let opts = ExpOptions { reps: 2, ..ExpOptions::quick() };
        let r = platform_scaling(&opts).unwrap();
        assert_eq!(r.figures.len(), 1);
        let fig = &r.figures[0];
        assert_eq!(fig.series.len(), 3);
        for s in &fig.series {
            assert_eq!(s.points.len(), node_counts().len(), "{}", s.label);
            for &(_, w) in &s.points {
                assert!((0.0..=1.0).contains(&w), "{}: waste {w}", s.label);
            }
        }
        assert!(fig.get("Young").is_some());
        assert!(fig.get("ExactPrediction").is_some());
        assert!(fig.get("Young@correlated").is_some());
        assert_eq!(r.tables.len(), 1);
        // Header + separator + 3 rows per node count.
        let rendered = r.tables[0].1.render();
        assert_eq!(rendered.lines().count(), 2 + 3 * node_counts().len());
    }

    #[test]
    fn uncorrelated_series_is_flat_in_k() {
        // Poisson superposition: the aggregate failure law is the same
        // at every K, so the Young waste at K = 8 must sit within a few
        // CI widths of K = 1. A coarse check with few reps — the tight
        // version lives in the conformance grid.
        let opts = ExpOptions { reps: 6, ..ExpOptions::quick() };
        let base = base_scenario();
        let spec = spec_for(StrategyKind::Young, &base, Capping::Uncapped);
        let mut at = |k: u64| {
            let p = PlatformSpec { nodes: k, ..PlatformSpec::default() };
            replicate_stat_with(
                opts.reps,
                opts.workers,
                || SimSession::new_on_platform(&base, &spec, &p).unwrap(),
                Outcome::waste,
            )
        };
        let one = at(1);
        let eight = at(8);
        let slack = 4.0 * (one.ci95() + eight.ci95()).max(0.02);
        assert!(
            (one.mean() - eight.mean()).abs() < slack,
            "K=1 {} vs K=8 {} (slack {slack})",
            one.mean(),
            eight.mean()
        );
    }
}
