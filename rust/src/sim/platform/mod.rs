//! Multi-node platform subsystem: a component-based discrete-event
//! layer in front of the unchanged [`crate::sim::Engine`].
//!
//! The paper's analysis targets platforms whose MTBF is the
//! superposition of N per-node fault streams (`mu = mu_ind / N`); the
//! engine historically simulated one aggregated stream. This module
//! simulates the platform *as components*:
//!
//! * [`core::EventHeap`] — the deterministic `(next_tick, component)`
//!   scheduler with stable tie-breaking;
//! * [`node::NodeStream`] — one per-node fault/prediction stream
//!   (K-scaled individual law, per-node seeded substreams);
//! * [`store::CheckpointStore`] — coordinated commits: all nodes
//!   quiesce, commit cost can scale with K and contend on the store,
//!   restarts are full or partial;
//! * [`correlate::Correlator`] — spatially correlated failure groups
//!   plus a depth-capped cascade kernel.
//!
//! [`PlatformSource`] merges it all into one [`EventSource`], so the
//! engine's event loop, policy layer and outcome accounting are reused
//! verbatim — the platform owns the *fault process*, not the
//! execution semantics. Two contracts fall out of the construction and
//! are pinned by tests:
//!
//! * **1-node identity**: `nodes = 1` replays the scenario seed's own
//!   substreams through an identity id-map and cost model — bit-
//!   identical to [`crate::sim::SimSession::from_policy`] on every
//!   [`crate::sim::Outcome`] field (`tests/test_platform.rs`);
//! * **superposition**: for exponential laws the merged K-node stream
//!   is statistically the single aggregated stream at `mu_ind / N`
//!   for *every* K (property-tested in `tests/test_properties.rs`),
//!   so the uncorrelated platform stays inside the closed form's
//!   domain and `verify::grid` asserts CI-band agreement; correlated
//!   and store-contended cases assert divergence bounds only.
//!
//! Multi-node platforms decline [`crate::trace::TraceBank`] replay
//! (live sessions only): a bank materializes one aggregated stream,
//! which is a different experiment than K merged per-node streams.

pub mod core;
pub mod correlate;
pub mod node;
pub mod store;

use std::fmt;
use std::str::FromStr;

use crate::config::Scenario;
use crate::trace::{EventSource, Fault, Prediction};

use self::core::EventHeap;
use self::correlate::Correlator;
use self::node::NodeStream;

/// Induced (correlated) faults carry ids from this disjoint high range
/// so they can never collide with the natural streams' remapped ids or
/// be linked to a prediction.
pub const INDUCED_ID_BASE: u64 = 1 << 62;

/// How a platform recovers after a fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RestartScope {
    /// Every node reloads its image from the store (contends like a
    /// commit).
    Full,
    /// Only the failed nodes reload; survivors roll back in place at
    /// constant cost.
    Partial,
}

/// Typed description of a simulated platform — the `--platform` /
/// wire-v2 `platform` / TOML `[platform]` surface, with the same
/// `FromStr`/`Display` discipline as [`crate::strategies::PolicySpec`].
///
/// The canonical string forms:
///
/// * `single` — the default: one node, no contention, no correlation;
///   exactly the classic single-stream engine (pinned bit-identical);
/// * `nodes=K[,commit=G][,restart=partial][,group=N][,spatial=P][,cascade=P][,delta=S]`
///   — only non-default keys are printed, every key is accepted.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlatformSpec {
    /// Number of nodes K (>= 1; 0 is rejected, not hung on).
    pub nodes: u64,
    /// Store-contention factor γ: a coordinated commit costs
    /// `C · (1 + γ·(K−1))` (0 = perfectly parallel store).
    pub commit: f64,
    /// Recovery scope after a fault.
    pub restart: RestartScope,
    /// Correlation group size (consecutive node indices; 1 = no
    /// grouping).
    pub group: u64,
    /// Probability a fault induces a fault on each other group member.
    pub spatial: f64,
    /// Probability an *induced* fault propagates one more hop.
    pub cascade: f64,
    /// Maximum induced-fault delay Δt (s): induced faults strike
    /// uniformly in `(t, t + delta]` after their trigger.
    pub delta: f64,
}

impl Default for PlatformSpec {
    fn default() -> PlatformSpec {
        PlatformSpec {
            nodes: 1,
            commit: 0.0,
            restart: RestartScope::Full,
            group: 1,
            spatial: 0.0,
            cascade: 0.0,
            delta: 300.0,
        }
    }
}

impl PlatformSpec {
    /// Whether this spec is the exact single-stream special case (the
    /// classic engine path; no platform layer needed).
    pub fn is_single(&self) -> bool {
        *self == PlatformSpec::default()
    }

    /// Whether the correlation layer is live (induced faults possible).
    pub fn correlated(&self) -> bool {
        self.spatial > 0.0 && self.nodes > 1 && self.group > 1
    }

    /// Reject parameterizations the platform cannot honor. `FromStr`
    /// calls this, so parsed specs are always valid.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.nodes >= 1, "platform needs at least one node (nodes = 0)");
        anyhow::ensure!(
            self.commit.is_finite() && self.commit >= 0.0,
            "platform commit factor must be finite and >= 0, got {}",
            self.commit
        );
        anyhow::ensure!(self.group >= 1, "platform correlation group must be >= 1");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.spatial),
            "platform spatial probability must be in [0, 1), got {}",
            self.spatial
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.cascade),
            "platform cascade probability must be in [0, 1), got {}",
            self.cascade
        );
        anyhow::ensure!(
            self.delta.is_finite() && self.delta > 0.0,
            "platform delta must be finite and > 0, got {}",
            self.delta
        );
        Ok(())
    }
}

impl fmt::Display for PlatformSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_single() {
            return write!(f, "single");
        }
        let d = PlatformSpec::default();
        write!(f, "nodes={}", self.nodes)?;
        if self.commit != d.commit {
            write!(f, ",commit={}", self.commit)?;
        }
        if self.restart == RestartScope::Partial {
            write!(f, ",restart=partial")?;
        }
        if self.group != d.group {
            write!(f, ",group={}", self.group)?;
        }
        if self.spatial != d.spatial {
            write!(f, ",spatial={}", self.spatial)?;
        }
        if self.cascade != d.cascade {
            write!(f, ",cascade={}", self.cascade)?;
        }
        if self.delta != d.delta {
            write!(f, ",delta={}", self.delta)?;
        }
        Ok(())
    }
}

impl FromStr for PlatformSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<PlatformSpec> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("single") {
            return Ok(PlatformSpec::default());
        }
        let mut spec = PlatformSpec::default();
        for part in s.split(',') {
            let part = part.trim();
            let (key, val) = part.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("platform spec needs key=value pairs, got '{part}'")
            })?;
            let (key, val) = (key.trim().to_ascii_lowercase(), val.trim());
            match key.as_str() {
                "nodes" => spec.nodes = val.parse().map_err(|_| bad(&key, val))?,
                "commit" => spec.commit = val.parse().map_err(|_| bad(&key, val))?,
                "restart" => {
                    spec.restart = match val.to_ascii_lowercase().as_str() {
                        "full" => RestartScope::Full,
                        "partial" => RestartScope::Partial,
                        _ => anyhow::bail!("platform restart must be 'full' or 'partial', got '{val}'"),
                    }
                }
                "group" => spec.group = val.parse().map_err(|_| bad(&key, val))?,
                "spatial" => spec.spatial = val.parse().map_err(|_| bad(&key, val))?,
                "cascade" => spec.cascade = val.parse().map_err(|_| bad(&key, val))?,
                "delta" => spec.delta = val.parse().map_err(|_| bad(&key, val))?,
                _ => anyhow::bail!(
                    "unknown platform key '{key}' (known: nodes, commit, restart, group, spatial, cascade, delta)"
                ),
            }
        }
        spec.validate()?;
        Ok(spec)
    }
}

fn bad(key: &str, val: &str) -> anyhow::Error {
    anyhow::anyhow!("platform {key}: cannot parse '{val}'")
}

/// The merged platform event source: K [`NodeStream`] components
/// scheduled by two [`EventHeap`]s (faults by strike time, predictions
/// by availability), with the [`Correlator`]'s induced-fault queue
/// racing the natural fault stream. Implements [`EventSource`], so the
/// engine cannot tell a platform from a single generator.
#[derive(Debug)]
pub struct PlatformSource {
    nodes: Vec<NodeStream>,
    // Peeked next event per node; the heaps index into these.
    peeked_faults: Vec<Option<Fault>>,
    peeked_preds: Vec<Option<Prediction>>,
    fault_heap: EventHeap,
    pred_heap: EventHeap,
    correlator: Option<Correlator>,
    induced_seq: u64,
}

impl PlatformSource {
    /// Build the platform for one replication. Mirrors
    /// [`crate::trace::TraceGen::new`]'s signature, extended by the
    /// spec; rejects `nodes = 0` with an error instead of an empty
    /// heap that would starve the engine.
    pub fn new(
        scenario: &Scenario,
        spec: &PlatformSpec,
        lead: f64,
        seed: u64,
        rep: u64,
    ) -> anyhow::Result<PlatformSource> {
        spec.validate()?;
        let mut nodes = Vec::with_capacity(spec.nodes as usize);
        for j in 0..spec.nodes {
            nodes.push(NodeStream::new(scenario, spec, lead, seed, rep, j)?);
        }
        let correlator = spec.correlated().then(|| Correlator::new(spec, seed, rep));
        let mut src = PlatformSource {
            peeked_faults: vec![None; nodes.len()],
            peeked_preds: vec![None; nodes.len()],
            nodes,
            fault_heap: EventHeap::new(),
            pred_heap: EventHeap::new(),
            correlator,
            induced_seq: 0,
        };
        src.prime();
        Ok(src)
    }

    /// Rewind to replication `rep` of `seed` — same contract as
    /// [`crate::trace::TraceGen::reset`], platform-wide.
    pub fn reset(&mut self, seed: u64, rep: u64) {
        for node in &mut self.nodes {
            node.reset(seed, rep);
        }
        if let Some(c) = &mut self.correlator {
            c.reset(seed, rep);
        }
        self.fault_heap.clear();
        self.pred_heap.clear();
        self.peeked_faults.iter_mut().for_each(|p| *p = None);
        self.peeked_preds.iter_mut().for_each(|p| *p = None);
        self.induced_seq = 0;
        self.prime();
    }

    /// Number of nodes.
    pub fn nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Peek each node's first fault/prediction into the heaps.
    fn prime(&mut self) {
        for j in 0..self.nodes.len() {
            self.refill_fault(j);
            self.refill_pred(j);
        }
    }

    fn refill_fault(&mut self, j: usize) {
        // Node generators are infinite, so this always schedules.
        if let Some(f) = self.nodes[j].next_fault() {
            self.fault_heap.push(f.t, j as u64);
            self.peeked_faults[j] = Some(f);
        }
    }

    fn refill_pred(&mut self, j: usize) {
        // A never-firing predictor yields None: the node simply never
        // appears in the prediction heap.
        if let Some(p) = self.nodes[j].next_prediction() {
            self.pred_heap.push(p.avail, j as u64);
            self.peeked_preds[j] = Some(p);
        }
    }
}

impl EventSource for PlatformSource {
    fn next_fault(&mut self) -> Option<Fault> {
        let natural_t = self.fault_heap.peek().map(|(t, _)| t).unwrap_or(f64::INFINITY);
        let induced_t = self
            .correlator
            .as_ref()
            .and_then(Correlator::peek_time)
            .unwrap_or(f64::INFINITY);
        // Ties go to the natural stream (deterministic; induced faults
        // are strictly later than their triggers anyway).
        if induced_t < natural_t {
            let correlator = self.correlator.as_mut().expect("peeked above");
            let i = correlator.pop().expect("peeked above");
            correlator.on_fault(i.node, i.t, i.depth);
            let id = INDUCED_ID_BASE + self.induced_seq;
            self.induced_seq += 1;
            return Some(Fault { t: i.t, id, predicted: false });
        }
        let (_, j) = self.fault_heap.pop()?;
        let j = j as usize;
        let fault = self.peeked_faults[j].take().expect("heap entry implies a peeked fault");
        if let Some(c) = &mut self.correlator {
            // node index = global id modulo K by the remap.
            c.on_fault(fault.id % self.nodes.len() as u64, fault.t, 0);
        }
        self.refill_fault(j);
        Some(fault)
    }

    fn next_prediction(&mut self) -> Option<Prediction> {
        let (_, j) = self.pred_heap.pop()?;
        let j = j as usize;
        let pred = self.peeked_preds[j].take().expect("heap entry implies a peeked prediction");
        self.refill_pred(j);
        Some(pred)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;
    use crate::trace::TraceGen;

    fn scenario(recall: f64, precision: f64, window: f64) -> Scenario {
        let pred = if window > 0.0 {
            Predictor::windowed(recall, precision, window)
        } else {
            Predictor::exact(recall, precision)
        };
        let mut s = Scenario::paper(1 << 16, pred);
        s.fault_dist = crate::dist::DistSpec::Exp;
        s.work = 2.0e5;
        s
    }

    #[test]
    fn spec_default_displays_as_single_and_round_trips() {
        let d = PlatformSpec::default();
        assert!(d.is_single());
        assert_eq!(d.to_string(), "single");
        assert_eq!("single".parse::<PlatformSpec>().unwrap(), d);
        assert_eq!("SINGLE".parse::<PlatformSpec>().unwrap(), d);
    }

    #[test]
    fn spec_round_trips_non_default_keys_only() {
        let specs = [
            PlatformSpec { nodes: 4, ..PlatformSpec::default() },
            PlatformSpec { nodes: 8, commit: 0.05, ..PlatformSpec::default() },
            PlatformSpec {
                nodes: 16,
                commit: 0.5,
                restart: RestartScope::Partial,
                group: 4,
                spatial: 0.25,
                cascade: 0.1,
                delta: 120.0,
            },
        ];
        for spec in specs {
            let s = spec.to_string();
            assert_eq!(s.parse::<PlatformSpec>().unwrap(), spec, "round-trip of '{s}'");
        }
        assert_eq!(
            PlatformSpec { nodes: 4, ..PlatformSpec::default() }.to_string(),
            "nodes=4"
        );
        assert_eq!(
            PlatformSpec { nodes: 8, commit: 0.05, ..PlatformSpec::default() }.to_string(),
            "nodes=8,commit=0.05"
        );
    }

    #[test]
    fn spec_rejects_bad_forms() {
        assert!("nodes=0".parse::<PlatformSpec>().is_err(), "empty platform");
        assert!("nodes=4,spatial=1.5".parse::<PlatformSpec>().is_err());
        assert!("nodes=4,restart=maybe".parse::<PlatformSpec>().is_err());
        assert!("nodes=4,bogus=1".parse::<PlatformSpec>().is_err());
        assert!("nodes=four".parse::<PlatformSpec>().is_err());
        assert!("nodes=4,delta=0".parse::<PlatformSpec>().is_err());
        assert!("".parse::<PlatformSpec>().is_err());
    }

    #[test]
    fn zero_nodes_is_an_error_not_a_hang() {
        let s = scenario(0.0, 1.0, 0.0);
        let spec = PlatformSpec { nodes: 0, ..PlatformSpec::default() };
        let err = PlatformSource::new(&s, &spec, 600.0, 1, 0).unwrap_err().to_string();
        assert!(err.contains("at least one node"), "{err}");
    }

    #[test]
    fn one_node_platform_is_the_plain_generator() {
        // Stream-level bit-identity at K = 1 (the session/outcome-level
        // pin lives in tests/test_platform.rs).
        let s = scenario(0.85, 0.82, 300.0);
        let spec = PlatformSpec::default();
        let mut platform = PlatformSource::new(&s, &spec, 600.0, s.seed, 0).unwrap();
        let mut plain = TraceGen::new(&s, 600.0, s.seed, 0).unwrap();
        for _ in 0..300 {
            assert_eq!(platform.next_fault(), plain.next_fault());
        }
        for _ in 0..100 {
            assert_eq!(platform.next_prediction(), plain.next_prediction());
        }
    }

    #[test]
    fn merged_streams_are_monotone() {
        let s = scenario(0.85, 0.82, 300.0);
        let spec = PlatformSpec { nodes: 6, ..PlatformSpec::default() };
        let mut src = PlatformSource::new(&s, &spec, 600.0, 3, 0).unwrap();
        let mut last = 0.0;
        for _ in 0..2000 {
            let f = src.next_fault().unwrap();
            assert!(f.t >= last, "fault stream went back in time");
            last = f.t;
        }
        let mut last = f64::NEG_INFINITY;
        for _ in 0..500 {
            let p = src.next_prediction().unwrap();
            assert!(p.avail >= last, "prediction stream went back in time");
            last = p.avail;
        }
    }

    #[test]
    fn correlated_platform_injects_unpredicted_high_id_faults() {
        let s = scenario(0.85, 0.82, 300.0);
        let spec = PlatformSpec {
            nodes: 8,
            group: 4,
            spatial: 0.5,
            cascade: 0.2,
            delta: 120.0,
            ..PlatformSpec::default()
        };
        let mut src = PlatformSource::new(&s, &spec, 600.0, 5, 0).unwrap();
        let mut induced = 0;
        let mut last = 0.0;
        for _ in 0..4000 {
            let f = src.next_fault().unwrap();
            assert!(f.t >= last, "induced faults must merge monotonically");
            last = f.t;
            if f.id >= INDUCED_ID_BASE {
                induced += 1;
                assert!(!f.predicted, "induced faults are unpredicted");
            }
        }
        assert!(induced > 100, "spatial=0.5 over groups of 4 must induce plenty, got {induced}");
    }

    #[test]
    fn uncorrelated_spec_never_builds_a_correlator() {
        let s = scenario(0.0, 1.0, 0.0);
        // spatial > 0 but group = 1: no neighbors, the layer is inert.
        let spec = PlatformSpec { nodes: 4, spatial: 0.5, ..PlatformSpec::default() };
        let src = PlatformSource::new(&s, &spec, 600.0, 1, 0).unwrap();
        assert!(src.correlator.is_none());
    }

    #[test]
    fn reset_matches_fresh_platform() {
        let s = scenario(0.85, 0.82, 300.0);
        let spec = PlatformSpec {
            nodes: 4,
            group: 2,
            spatial: 0.3,
            delta: 200.0,
            ..PlatformSpec::default()
        };
        let mut reused = PlatformSource::new(&s, &spec, 600.0, 13, 0).unwrap();
        for rep in [5u64, 0, 2] {
            reused.reset(13, rep);
            let mut fresh = PlatformSource::new(&s, &spec, 600.0, 13, rep).unwrap();
            for _ in 0..400 {
                assert_eq!(reused.next_fault(), fresh.next_fault());
            }
            for _ in 0..100 {
                assert_eq!(reused.next_prediction(), fresh.next_prediction());
            }
        }
    }
}
