//! Failure-law distributions (substrate: no `rand_distr` offline).
//!
//! [`Dist`] is the workhorse: a monomorphized enum over the three laws
//! the paper simulates (Exponential, Weibull, Uniform) with inline
//! inverse-CDF sampling — the trace generator draws one sample per
//! fault and per false prediction, so the sampling call sits on the
//! replication hot path and must not go through `Box<dyn>` virtual
//! dispatch. The thin [`Distribution`] trait (and the per-law structs)
//! exists only for the `prelude` API and generic user code; everything
//! inside the engine stores `Dist` by value.
//!
//! [`DistSpec`] is the *typed* failure-law specification carried by
//! [`crate::config::Scenario`]: the three laws as data, with
//! `FromStr`/`Display` doing the string conversion exactly once at the
//! wire edge (JSONL protocol, TOML files, CLI flags). Spec strings:
//!
//! * `"exp"` (or `"exponential"`) — Exponential;
//! * `"weibull:K"` — Weibull with shape `K` (e.g. `weibull:0.7`);
//! * `"uniform"` — Uniform on `[0, 2·mean]`.
//!
//! [`parse`] yields a unit-mean law; scale it with [`Dist::with_mean`].

use crate::rng::Pcg64;

/// A continuous positive distribution, monomorphized for the sampling
/// hot loop. All variants are parameterized so that [`Dist::mean`] is
/// exact and [`Dist::with_mean`] is a pure rescale.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Dist {
    /// Exponential with the given mean (rate 1/mean).
    Exponential { mean: f64 },
    /// Weibull with shape `k` and scale `lambda`.
    Weibull { shape: f64, scale: f64 },
    /// Uniform on `[lo, hi)`.
    Uniform { lo: f64, hi: f64 },
}

impl Dist {
    /// Inverse-CDF sample. Uses the open-interval uniform so `ln` never
    /// sees zero; one RNG draw per sample for every law.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match *self {
            Dist::Exponential { mean } => -mean * rng.next_f64_open().ln(),
            Dist::Weibull { shape, scale } => {
                scale * (-rng.next_f64_open().ln()).powf(1.0 / shape)
            }
            Dist::Uniform { lo, hi } => lo + (hi - lo) * rng.next_f64(),
        }
    }

    /// Exact expectation of the law.
    pub fn mean(&self) -> f64 {
        match *self {
            Dist::Exponential { mean } => mean,
            Dist::Weibull { shape, scale } => scale * gamma(1.0 + 1.0 / shape),
            Dist::Uniform { lo, hi } => 0.5 * (lo + hi),
        }
    }

    /// Rescale so the expectation equals `mean` (shape is preserved).
    pub fn with_mean(self, mean: f64) -> Dist {
        match self {
            Dist::Exponential { .. } => Dist::Exponential { mean },
            Dist::Weibull { shape, .. } => {
                Dist::Weibull { shape, scale: mean / gamma(1.0 + 1.0 / shape) }
            }
            Dist::Uniform { .. } => Dist::Uniform { lo: 0.0, hi: 2.0 * mean },
        }
    }
}

/// Typed failure-law specification — the form a law takes *outside*
/// the sampling hot path. A [`crate::config::Scenario`] stores one of
/// these; strings appear only at the wire edge, through the `FromStr`
/// and `Display` impls (which round-trip: `spec.to_string().parse()`
/// gives back `spec` for every valid value).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DistSpec {
    /// Exponential (memoryless) inter-arrivals — spec string `"exp"`.
    Exp,
    /// Weibull inter-arrivals with the given shape — `"weibull:K"`.
    Weibull { shape: f64 },
    /// Uniform on `[0, 2·mean]` — `"uniform"`.
    Uniform,
}

impl DistSpec {
    /// Weibull spec with shape `k` (validated later, see
    /// [`DistSpec::validate`]).
    pub fn weibull(shape: f64) -> DistSpec {
        DistSpec::Weibull { shape }
    }

    /// Reject parameterizations the sampler cannot honor. `FromStr`
    /// already enforces this; direct construction goes through here via
    /// `Scenario::validate`.
    pub fn validate(&self) -> anyhow::Result<()> {
        if let DistSpec::Weibull { shape } = self {
            anyhow::ensure!(
                shape.is_finite() && *shape > 0.0,
                "Weibull shape must be finite and positive in distribution spec '{}'",
                self
            );
        }
        Ok(())
    }

    /// Materialize the unit-mean sampling law. Fails (naming the spec)
    /// on invalid parameterizations instead of sampling NaNs.
    pub fn dist(&self) -> anyhow::Result<Dist> {
        self.validate()?;
        Ok(match *self {
            DistSpec::Exp => Dist::Exponential { mean: 1.0 },
            DistSpec::Weibull { shape } => Dist::Weibull { shape, scale: 1.0 }.with_mean(1.0),
            DistSpec::Uniform => Dist::Uniform { lo: 0.0, hi: 2.0 },
        })
    }
}

impl std::fmt::Display for DistSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DistSpec::Exp => f.write_str("exp"),
            DistSpec::Weibull { shape } => write!(f, "weibull:{shape}"),
            DistSpec::Uniform => f.write_str("uniform"),
        }
    }
}

impl std::str::FromStr for DistSpec {
    type Err = anyhow::Error;

    fn from_str(spec: &str) -> anyhow::Result<DistSpec> {
        let spec_trim = spec.trim();
        match spec_trim {
            "exp" | "exponential" => return Ok(DistSpec::Exp),
            "uniform" => return Ok(DistSpec::Uniform),
            _ => {}
        }
        if let Some(shape_str) = spec_trim.strip_prefix("weibull:") {
            let shape: f64 = shape_str.parse().map_err(|_| {
                anyhow::anyhow!("bad Weibull shape in distribution spec '{spec}' (expected weibull:<shape>, e.g. weibull:0.7)")
            })?;
            anyhow::ensure!(
                shape.is_finite() && shape > 0.0,
                "Weibull shape must be finite and positive in distribution spec '{spec}'"
            );
            return Ok(DistSpec::Weibull { shape });
        }
        anyhow::bail!(
            "unrecognized distribution spec '{spec}' (expected \"exp\", \"weibull:<shape>\" or \"uniform\")"
        )
    }
}

/// Parse a spec string straight into a unit-mean law — the one-step
/// wire-edge helper. The error always names the offending spec so
/// validation failures are actionable.
pub fn parse(spec: &str) -> anyhow::Result<Dist> {
    spec.parse::<DistSpec>()?.dist()
}

/// Γ(x) for x > 0 — Lanczos approximation (g = 7, n = 9), accurate to
/// ~1e-13 over the shapes used here. Needed for the Weibull mean.
fn gamma(x: f64) -> f64 {
    const COEFFS: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula keeps the approximation in its sweet spot.
        return std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma(1.0 - x));
    }
    let x = x - 1.0;
    let mut acc = COEFFS[0];
    for (i, c) in COEFFS.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + 7.5;
    (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * acc
}

/// Object-safe view of a distribution, for the prelude / generic user
/// code. The engine never goes through this — it stores [`Dist`].
pub trait Distribution {
    fn sample(&self, rng: &mut Pcg64) -> f64;
    fn mean(&self) -> f64;
}

impl Distribution for Dist {
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        Dist::sample(self, rng)
    }

    fn mean(&self) -> f64 {
        Dist::mean(self)
    }
}

/// Exponential law (prelude convenience wrapper over [`Dist`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Exponential {
    pub mean: f64,
}

impl Exponential {
    pub fn new(mean: f64) -> Self {
        Exponential { mean }
    }
}

impl From<Exponential> for Dist {
    fn from(e: Exponential) -> Dist {
        Dist::Exponential { mean: e.mean }
    }
}

impl Distribution for Exponential {
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        Dist::from(*self).sample(rng)
    }

    fn mean(&self) -> f64 {
        self.mean
    }
}

/// Weibull law (prelude convenience wrapper over [`Dist`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Weibull {
    pub shape: f64,
    pub scale: f64,
}

impl Weibull {
    pub fn new(shape: f64, scale: f64) -> Self {
        Weibull { shape, scale }
    }

    /// Weibull with shape `k`, scaled to the given mean.
    pub fn with_mean(shape: f64, mean: f64) -> Self {
        match (Dist::Weibull { shape, scale: 1.0 }).with_mean(mean) {
            Dist::Weibull { shape, scale } => Weibull { shape, scale },
            _ => unreachable!(),
        }
    }
}

impl From<Weibull> for Dist {
    fn from(w: Weibull) -> Dist {
        Dist::Weibull { shape: w.shape, scale: w.scale }
    }
}

impl Distribution for Weibull {
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        Dist::from(*self).sample(rng)
    }

    fn mean(&self) -> f64 {
        Dist::from(*self).mean()
    }
}

/// Uniform law (prelude convenience wrapper over [`Dist`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Uniform {
    pub lo: f64,
    pub hi: f64,
}

impl Uniform {
    pub fn new(lo: f64, hi: f64) -> Self {
        Uniform { lo, hi }
    }
}

impl From<Uniform> for Dist {
    fn from(u: Uniform) -> Dist {
        Dist::Uniform { lo: u.lo, hi: u.hi }
    }
}

impl Distribution for Uniform {
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        Dist::from(*self).sample(rng)
    }

    fn mean(&self) -> f64 {
        Dist::from(*self).mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    fn empirical_mean(d: Dist, n: usize, seed: u64) -> f64 {
        let mut rng = Pcg64::seeded(seed);
        (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64
    }

    #[test]
    fn parse_known_specs() {
        assert_eq!(parse("exp").unwrap(), Dist::Exponential { mean: 1.0 });
        assert_eq!(parse("exponential").unwrap(), Dist::Exponential { mean: 1.0 });
        assert_eq!(parse("uniform").unwrap(), Dist::Uniform { lo: 0.0, hi: 2.0 });
        match parse("weibull:0.7").unwrap() {
            Dist::Weibull { shape, scale } => {
                assert!(approx_eq(shape, 0.7, 1e-12));
                assert!(scale > 0.0);
            }
            other => panic!("wrong law: {other:?}"),
        }
    }

    #[test]
    fn parse_yields_unit_mean() {
        for spec in ["exp", "uniform", "weibull:0.5", "weibull:0.7", "weibull:1.0", "weibull:2.0"] {
            let d = parse(spec).unwrap();
            assert!(approx_eq(d.mean(), 1.0, 1e-9), "{spec}: mean {}", d.mean());
        }
    }

    #[test]
    fn spec_round_trips_through_display() {
        for spec in [DistSpec::Exp, DistSpec::weibull(0.7), DistSpec::weibull(2.0), DistSpec::Uniform] {
            let s = spec.to_string();
            assert_eq!(s.parse::<DistSpec>().unwrap(), spec, "round-trip of '{s}'");
        }
        assert_eq!("exponential".parse::<DistSpec>().unwrap(), DistSpec::Exp);
    }

    #[test]
    fn spec_validate_catches_bad_shapes() {
        assert!(DistSpec::weibull(0.0).validate().is_err());
        assert!(DistSpec::weibull(f64::NAN).validate().is_err());
        assert!(DistSpec::weibull(-1.0).dist().is_err());
        let err = DistSpec::weibull(-1.0).validate().unwrap_err().to_string();
        assert!(err.contains("weibull:-1"), "error must name the spec: {err}");
        DistSpec::Exp.validate().unwrap();
        DistSpec::Uniform.validate().unwrap();
    }

    #[test]
    fn spec_dist_matches_parse() {
        for s in ["exp", "uniform", "weibull:0.7"] {
            assert_eq!(s.parse::<DistSpec>().unwrap().dist().unwrap(), parse(s).unwrap());
        }
    }

    #[test]
    fn parse_error_names_the_spec() {
        for bad in ["bogus", "weibull:", "weibull:zero", "weibull:-1", "weibull:nan"] {
            let err = parse(bad).unwrap_err().to_string();
            assert!(err.contains(bad), "error for '{bad}' does not name it: {err}");
        }
    }

    #[test]
    fn with_mean_rescales_exactly() {
        for spec in ["exp", "uniform", "weibull:0.7"] {
            let d = parse(spec).unwrap().with_mean(60_000.0);
            assert!(approx_eq(d.mean(), 60_000.0, 1e-9), "{spec}: mean {}", d.mean());
        }
    }

    #[test]
    fn gamma_known_values() {
        // Γ(n) = (n-1)!, Γ(1/2) = sqrt(pi).
        assert!(approx_eq(gamma(1.0), 1.0, 1e-12));
        assert!(approx_eq(gamma(2.0), 1.0, 1e-12));
        assert!(approx_eq(gamma(5.0), 24.0, 1e-12));
        assert!(approx_eq(gamma(0.5), std::f64::consts::PI.sqrt(), 1e-12));
        // Weibull k=0.7 mean factor Γ(1 + 1/0.7) = Γ(2.428...).
        assert!(approx_eq(gamma(1.0 + 1.0 / 0.7), 1.265857127050092, 1e-9));
    }

    #[test]
    fn empirical_means_match() {
        let n = 200_000;
        for (spec, seed) in [("exp", 1), ("uniform", 2), ("weibull:0.7", 3), ("weibull:2.0", 4)] {
            let d = parse(spec).unwrap().with_mean(100.0);
            let emp = empirical_mean(d, n, seed);
            assert!(
                (emp - 100.0).abs() / 100.0 < 0.03,
                "{spec}: empirical mean {emp}"
            );
        }
    }

    #[test]
    fn exponential_memoryless_rate() {
        // P(X > t) = exp(-t/mean): check one tail point empirically.
        let d = Dist::Exponential { mean: 50.0 };
        let mut rng = Pcg64::seeded(9);
        let n = 100_000;
        let tail = (0..n).filter(|_| d.sample(&mut rng) > 50.0).count() as f64 / n as f64;
        assert!((tail - (-1.0f64).exp()).abs() < 0.01, "tail {tail}");
    }

    #[test]
    fn sampling_is_deterministic() {
        let d = parse("weibull:0.7").unwrap().with_mean(1000.0);
        let mut a = Pcg64::seeded(7);
        let mut b = Pcg64::seeded(7);
        for _ in 0..100 {
            assert_eq!(d.sample(&mut a), d.sample(&mut b));
        }
    }

    #[test]
    fn trait_objects_still_work() {
        // The prelude API: dyn-compatible trait over the wrappers.
        let laws: Vec<Box<dyn Distribution>> = vec![
            Box::new(Exponential::new(10.0)),
            Box::new(Weibull::with_mean(0.7, 10.0)),
            Box::new(Uniform::new(0.0, 20.0)),
        ];
        let mut rng = Pcg64::seeded(5);
        for law in &laws {
            assert!(approx_eq(law.mean(), 10.0, 1e-9));
            assert!(law.sample(&mut rng) >= 0.0);
        }
    }
}
